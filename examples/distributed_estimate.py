"""Distributed Dynamic Prober over an 8-device mesh (shard_map + psum):
the dataset is partitioned, every shard probes locally, cardinality is the
psum of local estimates (DESIGN.md §4).

  PYTHONPATH=src python examples/distributed_estimate.py
  (sets its own XLA_FLAGS; run as a standalone script)
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax                                             # noqa: E402
import jax.numpy as jnp                                # noqa: E402

from repro import compat                               # noqa: E402
from repro.core import distributed as D, estimator as E  # noqa: E402
from repro.core.config import ProberConfig             # noqa: E402

print("devices:", len(jax.devices()))
mesh = compat.make_mesh((8,), ("data",))

key = jax.random.PRNGKey(0)
x = jax.random.normal(key, (16000, 64))
cfg = ProberConfig(n_tables=2, n_funcs=8, ring_budget=1024,
                   central_budget=1024, chunk=128)

state, params = D.build_sharded(x, cfg, key, mesh)
print("sharded index built: 8 local partitions of", x.shape[0] // 8)

qs = x[:4] + 0.01
d2 = jnp.sort(jnp.sum((x - qs[0][None]) ** 2, axis=-1))
taus = jnp.sqrt(d2[jnp.array([10, 100, 500, 2000])]) + 1e-6
for mode in ("local", "sync"):
    ests = D.estimate_sharded(state, qs[:1].repeat(4, 0), taus, cfg, key,
                              mesh, mode=mode)
    for i, t in enumerate([10, 100, 500, 2000]):
        true = float(E.true_cardinality(x, qs[0], taus[i]))
        print(f"[{mode}] target={t:5d} estimate={float(ests[i]):8.1f} "
              f"true={true:6.0f}")
