"""Paper §5 walkthrough: build on 10% of the data, stream the rest in as
recompile-free capacity-padded updates (DESIGN.md §10), and compare
accuracy/time against a from-scratch rebuild.

  PYTHONPATH=src python examples/dynamic_updates.py
"""
import time

import jax

from repro.core import estimator as E, updates
from repro.core.config import ProberConfig
from repro.data import vectors

key = jax.random.PRNGKey(0)
ds = vectors.load("glove", n_queries=4, scale=0.15)
n = ds.x.shape[0]
n0 = int(n * 0.1) // 4 * 4
cfg = ProberConfig(n_tables=2, n_funcs=10, ring_budget=2048,
                   central_budget=2048, chunk=128)

t0 = time.time()
# capacity-padded build: spare rows make every in-capacity update ONE cached
# jitted step — no recompilation until the capacity doubles
state = E.build(ds.x[:n0], cfg, key, capacity=updates.next_pow2(n))
print(f"initial build on {n0} pts (capacity {state.capacity}): "
      f"{time.time()-t0:.2f}s")

CHUNK = 1024                                 # fixed shape => one compile
t0 = time.time()
state = E.update(state, ds.x[n0:n0 + CHUNK], cfg)   # Alg. 7/8 (+ compile)
t_first = time.time() - t0
t0 = time.time()
for i in range(n0 + CHUNK, n, CHUNK):
    state = E.update(state, ds.x[i:i + CHUNK], cfg)
jax.block_until_ready(state.index.order)
t_rest = time.time() - t0
n_rest = n - n0 - CHUNK
print(f"first chunk (compiles):    {t_first:.2f}s")
print(f"stream {n_rest} pts:          {t_rest:.2f}s "
      f"({n_rest / max(t_rest, 1e-9):,.0f} pts/s amortized)")
assert int(state.n_valid) == n

t0 = time.time()
static = E.build(ds.x, cfg, key)
print(f"from-scratch rebuild:      {time.time()-t0:.2f}s")


def mean_qerr(st):
    errs = []
    for qi in range(4):
        for t in range(0, ds.taus.shape[1], 2):
            est = float(E.estimate(st, ds.queries[qi], ds.taus[qi, t], cfg,
                                   jax.random.PRNGKey(qi * 31 + t)))
            c = max(float(ds.cards[qi, t]), 1.0)
            errs.append(max(max(est, 1) / c, c / max(est, 1)))
    return sum(errs) / len(errs)


print(f"mean Q-error  updated framework: {mean_qerr(state):.2f}")
print(f"mean Q-error  static build:      {mean_qerr(static):.2f}")
print("=> updates preserve accuracy (paper Fig. 7) without rebuilds")
