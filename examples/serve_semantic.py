"""End-to-end driver: semantic-operator serving with CE-planned LLM batches.

The paper's motivating application (§1): a semantic operator must know HOW
MANY corpus items match ``similarity(q) <= tau`` BEFORE calling the LLM on
each match. This driver runs the whole path on a reduced qwen2-family model:

  1. corpus of document embeddings -> Dynamic Prober index
  2. operator arrives (query embedding, tau, prompt template)
  3. planner estimates match cardinality -> execution plan (or refusal)
  4. matching docs (exact pass over the planned candidate set) are batched
     through the serving engine (prefill + decode with KV cache slots)
  5. repeated operator traffic (DESIGN.md §12): the planner's estimate
     cache serves zipfian repeat plans without re-probing — and a corpus
     update invalidates exactly the entries whose probed buckets changed

  PYTHONPATH=src python examples/serve_semantic.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core.config import ProberConfig
from repro.models import get_family
from repro.serve.engine import Request, ServeEngine
from repro.serve.semantic import SemanticPlanner

key = jax.random.PRNGKey(0)

# --- 1. document corpus (synthetic embeddings standing in for an encoder) --
N_DOCS, EMB_D = 4000, 64
corpus = jax.random.normal(key, (N_DOCS, EMB_D))
cfg = ProberConfig(n_tables=2, n_funcs=8, ring_budget=1024,
                   central_budget=1024, chunk=128)
# cache_size switches on the workload-aware estimate cache (DESIGN.md §12):
# repeated operator (q, tau) plans are served without re-running the probe
planner = SemanticPlanner(corpus, cfg, key, max_calls=64, slot_budget=4,
                          capacity=8192, cache_size=256, reuse_tol=0.0)
print(f"indexed {N_DOCS} docs")

# --- 2. a tiny LLM behind the serving engine ------------------------------
mcfg = configs.get_smoke_config("qwen2-7b")
fam = get_family(mcfg)
params = fam.init(jax.random.PRNGKey(1), mcfg)
engine = ServeEngine(mcfg, params, batch_slots=4, max_len=64)

# --- 3. semantic operators with varying selectivity -----------------------
for name, q, tau in [
    ("narrow", corpus[7], 4.0),
    ("medium", corpus[7], 8.5),
    ("too-broad", corpus[7], 50.0),
]:
    t0 = time.time()
    plan = planner.plan(q, tau)
    t_plan = 1e3 * (time.time() - t0)
    print(f"\noperator[{name}] tau={tau}: est={plan.est_matches:.1f} "
          f"action={plan.action} ({t_plan:.1f} ms to plan)  {plan.reason}")
    if plan.action != "execute" or plan.llm_calls == 0:
        continue
    # exact match set, capped by the planned call budget
    d2 = jnp.sum((corpus - q[None]) ** 2, axis=-1)
    matches = np.asarray(jnp.argsort(d2)[: plan.llm_calls])
    rng = np.random.default_rng(0)
    for i, doc_id in enumerate(matches):
        prompt = rng.integers(2, mcfg.vocab, size=8)   # stub doc tokens
        engine.submit(Request(rid=int(doc_id), prompt=prompt, max_new=6))
    t0 = time.time()
    done = engine.run()
    dt = time.time() - t0
    print(f"  executed {len(done)} LLM calls in {dt:.2f}s "
          f"({plan.n_batches} planned batches x {plan.batch_slots} slots)")

# --- 4. repeated operator traffic hits the estimate cache -----------------
# many clients re-ask the same few operators (zipfian repeats): after the
# first probe, plans come out of the LSH-keyed cache (DESIGN.md §12)
rng = np.random.default_rng(1)
heads = [(corpus[i], float(t)) for i in (7, 21, 99) for t in (6.0, 8.5)]
ranks = 1.0 / np.arange(1, len(heads) + 1) ** 0.99
t0 = time.time()
for r in rng.choice(len(heads), size=200, p=ranks / ranks.sum()):
    planner.plan(*heads[r])
dt = time.time() - t0
stats = planner.cache_stats
print(f"\n200 repeat plans in {dt:.2f}s "
      f"({200 / dt:.0f} plans/s): hit-rate "
      f"{stats['hits'] / max(stats['lookups'], 1):.2f} "
      f"(hits={stats['hits']} misses={stats['misses']} "
      f"evicts={stats['evicts']})")

# --- 5. corpus grows; planner absorbs it via paper §5 updates -------------
# the update invalidates exactly the cached plans whose probed buckets the
# new docs landed in (epoch check) — plans never reflect a stale corpus
planner.update_corpus(jax.random.normal(jax.random.PRNGKey(2), (1000, EMB_D)))
plan = planner.plan(corpus[7], 8.5)
stats = planner.cache_stats
print(f"\nafter +1000 docs: est={plan.est_matches:.1f} action={plan.action} "
      f"(stale-refreshes so far: {stats['stale']})")
