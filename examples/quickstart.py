"""Quickstart: build the Dynamic Prober, estimate cardinalities, compare to
ground truth, then apply a dynamic update (paper Alg. 1–9 in ~40 lines).

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import estimator as E
from repro.core.config import ProberConfig
from repro.data import vectors

key = jax.random.PRNGKey(0)
ds = vectors.load("sift", n_queries=4, scale=0.2)       # 8k x 128 surrogate
print(f"corpus: {ds.x.shape}")

cfg = ProberConfig(n_tables=2, n_funcs=10, ring_budget=2048,
                   central_budget=2048, chunk=128, eps=0.01)
state = E.build(ds.x, cfg, key)
print(f"built LSH index: {int(state.index.n_buckets[0])} buckets/table")

print(f"{'tau':>8} {'true':>6} {'estimate':>9} {'q-error':>8}")
for t in range(0, ds.taus.shape[1], 2):
    tau, true = ds.taus[0, t], float(ds.cards[0, t])
    est = float(E.estimate(state, ds.queries[0], tau, cfg,
                           jax.random.PRNGKey(t)))
    q = max(max(est, 1) / max(true, 1), max(true, 1) / max(est, 1))
    print(f"{float(tau):8.2f} {true:6.0f} {est:9.1f} {q:8.2f}")

# dynamic update (paper §5): append fresh points, estimates stay calibrated
# (state.x is capacity-padded after the update — mask truth by n_valid)
new_points = jax.random.normal(key, (1024, ds.x.shape[1])) * 0.1 + ds.x[:1024]
state = E.update(state, new_points, cfg)
est = float(E.estimate(state, ds.queries[0], ds.taus[0, 6], cfg, key))
true = float(E.true_cardinality(state.x, ds.queries[0], ds.taus[0, 6],
                                n_valid=state.n_valid))
print(f"after +1024 points: estimate={est:.1f} true={true:.0f}")
