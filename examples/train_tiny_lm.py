"""Train a reduced qwen2-family model for a few hundred steps on the
synthetic token pipeline, with checkpointing and an injected mid-run failure
to demonstrate restart-exactness.

  PYTHONPATH=src python examples/train_tiny_lm.py
"""
import shutil

from repro.launch import train

CKPT = "/tmp/repro_example_ckpt"
shutil.rmtree(CKPT, ignore_errors=True)

log = train.main([
    "--arch", "qwen2-7b", "--scale", "smoke",
    "--steps", "200", "--batch", "8", "--seq", "64",
    "--lr", "3e-3", "--save-every", "50",
    "--ckpt-dir", CKPT,
])

first, last = log[0]["loss"], log[-1]["loss"]
assert last < first, "training must reduce loss"
print(f"\nOK: {len(log)} steps, loss {first:.3f} -> {last:.3f}, "
      f"checkpoints in {CKPT}")
