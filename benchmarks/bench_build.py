"""Paper Fig. 2/3: offline construction latency + per-phase breakdown
(LSH index / neighbor table / optional PQ) vs the learned baseline's
training time."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks import common
from repro.core import lsh, neighbors, pq as pqmod
from repro.core.config import ProberConfig


def run(datasets=None):
    rows = []
    for name in datasets or common.DATASETS:
        ds = common.dataset(name)
        d = ds.x.shape[1]
        cfg = common.prober_cfg(True, d)
        key = jax.random.PRNGKey(0)

        t0 = time.time()
        idx = lsh.build_index(ds.x, cfg, key)
        jax.block_until_ready(idx.order)
        t_lsh = time.time() - t0

        t0 = time.time()
        nb = int(idx.n_buckets[0])
        codes = idx.bucket_codes[0][:nb]
        table = neighbors.build(codes, jnp.int32(nb), cfg.table_max_dist)
        jax.block_until_ready(table.dists)
        t_tab = time.time() - t0

        t0 = time.time()
        pq = pqmod.fit(ds.x, cfg, key)
        jax.block_until_ready(pq.codes)
        t_pq = time.time() - t0

        t0 = time.time()
        common.eval_mlp(ds)
        t_mlp = time.time() - t0

        rows.append({"dataset": name, "lsh_s": t_lsh, "table_s": t_tab,
                     "pq_s": t_pq, "mlp_train_s": t_mlp})
        print(f"[build] {name:9s} lsh={t_lsh:6.2f}s table={t_tab:6.2f}s "
              f"pq={t_pq:6.2f}s | mlp-train={t_mlp:6.2f}s")
    return rows


if __name__ == "__main__":
    run()
