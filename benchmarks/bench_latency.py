"""Paper Table 4: online estimation latency (ms/query) per dataset × method.

Absolute numbers are CPU-host values (the paper used a 160-thread Xeon); the
claim validated is the RELATIVE ordering — PQ < exact for high-d, both
competitive with sampling.

``--batch-sweep`` (or :func:`run_batch_sweep`) measures the batched path
instead: queries/sec and per-query p50 latency of ``estimate_batch`` at
Q ∈ {1, 8, 64, 256}, validating that coalescing amortises the hash matmul
and candidate scan (DESIGN.md §9). Output rows:
``{"dataset", "batch", "p50_ms_per_query", "qps", "speedup_vs_base"}``.
"""
from __future__ import annotations

import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import estimator as E

BATCH_SIZES = (1, 8, 64, 256)


def run(datasets=None):
    rows = []
    for name in datasets or common.DATASETS:
        ds = common.dataset(name)
        d = ds.x.shape[1]
        for meth, fn in {
            "DynamicProber": lambda: common.eval_prober(
                ds, common.prober_cfg(False, d)),
            "DynamicProber-PQ": lambda: common.eval_prober(
                ds, common.prober_cfg(True, d)),
            "Sampling1%": lambda: common.eval_sampling(ds, 0.01),
            "MLP-lite": lambda: common.eval_mlp(ds),
        }.items():
            out = fn()
            rows.append({"dataset": name, "method": meth,
                         "ms_per_query": out["ms_per_query"]})
            print(f"[latency] {name:9s} {meth:16s} "
                  f"{out['ms_per_query']:8.2f} ms/query")
    return rows


def run_batch_sweep(batch_sizes=BATCH_SIZES, dataset: str = "sift",
                    pool: int = 256, reps: int = 5):
    """Throughput/latency of ``estimate_batch`` vs batch size Q.

    A fixed pool of ``pool`` (query, tau) requests is processed at every
    batch size — Q=1 is the per-request dispatch baseline, larger Q
    coalesces the same workload into pool/Q jitted steps — using the
    throughput-tuned :func:`common.serve_cfg`. Measurement rounds are
    INTERLEAVED across batch sizes so ambient load on a shared/throttled
    host biases every Q equally. Reported per Q: p50 per-query latency
    (median per-batch wall time / Q) and queries/sec (Q / p50 batch time).
    """
    assert pool >= max(batch_sizes), \
        f"pool={pool} must cover the largest batch size {max(batch_sizes)}"
    ds = common.dataset(dataset)
    cfg = common.serve_cfg(ds.x.shape[1])
    st = E.build(ds.x, cfg, jax.random.PRNGKey(0))
    jax.block_until_ready(st.index.order)
    rng = np.random.default_rng(0)
    queries = np.asarray(ds.queries)
    taus_all = np.asarray(ds.taus)
    qi = rng.integers(0, queries.shape[0], pool)
    ti = rng.integers(0, taus_all.shape[1], pool)
    qs = jnp.asarray(queries[qi])
    taus = jnp.asarray(taus_all[qi, ti])
    for q in batch_sizes:                                # compile per shape
        E.estimate_batch(st, qs[:q], taus[:q], cfg,
                         jax.random.PRNGKey(0)).block_until_ready()
    times: dict[int, list[float]] = {q: [] for q in batch_sizes}
    for r in range(reps):
        for q in batch_sizes:
            for b in range(max(pool // q, 1)):
                lo = b * q
                t0 = time.perf_counter()
                E.estimate_batch(st, qs[lo:lo + q], taus[lo:lo + q], cfg,
                                 jax.random.PRNGKey(r * pool + b)
                                 ).block_until_ready()
                times[q].append(time.perf_counter() - t0)
    rows = []
    base_q, base_qps = batch_sizes[0], None
    for q in batch_sizes:
        p50 = float(np.percentile(times[q], 50))
        qps = q / p50
        base_qps = qps if base_qps is None else base_qps
        rows.append({"dataset": dataset, "batch": q,
                     "p50_ms_per_query": 1e3 * p50 / q, "qps": qps,
                     "speedup_vs_base": qps / base_qps})
        print(f"[latency-batch] {dataset:9s} Q={q:4d} "
              f"{1e3 * p50 / q:8.3f} ms/query p50  {qps:10.1f} q/s  "
              f"({qps / base_qps:5.2f}x vs Q={base_q})")
    return rows


if __name__ == "__main__":
    if "--batch-sweep" in sys.argv[1:]:
        run_batch_sweep()
    else:
        run()
