"""Paper Table 4: online estimation latency (ms/query) per dataset × method.

Absolute numbers are CPU-host values (the paper used a 160-thread Xeon); the
claim validated is the RELATIVE ordering — PQ < exact for high-d, both
competitive with sampling.
"""
from __future__ import annotations

from benchmarks import common


def run(datasets=None):
    rows = []
    for name in datasets or common.DATASETS:
        ds = common.dataset(name)
        d = ds.x.shape[1]
        for meth, fn in {
            "DynamicProber": lambda: common.eval_prober(
                ds, common.prober_cfg(False, d)),
            "DynamicProber-PQ": lambda: common.eval_prober(
                ds, common.prober_cfg(True, d)),
            "Sampling1%": lambda: common.eval_sampling(ds, 0.01),
            "MLP-lite": lambda: common.eval_mlp(ds),
        }.items():
            out = fn()
            rows.append({"dataset": name, "method": meth,
                         "ms_per_query": out["ms_per_query"]})
            print(f"[latency] {name:9s} {meth:16s} "
                  f"{out['ms_per_query']:8.2f} ms/query")
    return rows


if __name__ == "__main__":
    run()
