"""Paper Table 4: online estimation latency (ms/query) per dataset × method.

Absolute numbers are CPU-host values (the paper used a 160-thread Xeon); the
claim validated is the RELATIVE ordering — PQ < exact for high-d, both
competitive with sampling.

``--batch-sweep`` (or :func:`run_batch_sweep`) measures the batched path
instead: queries/sec and per-query p50 latency of ``estimate_batch`` at
Q ∈ {1, 8, 64, 256}, validating that coalescing amortises the hash matmul
and candidate scan (DESIGN.md §9). The sweep runs TWO workload mixes —
``uniform`` (taus drawn uniformly from the dataset's radius grid, under
the §9 throughput-truncated ``serve_cfg``) and ``skew`` (a heavy-tailed
mix where ~1/8 of the requests carry a large tau and the rest a small
one, under the ε-faithful adaptive stopping config — see
:func:`adaptive_cfg`), the workload the compacting lane scheduler
(DESIGN.md §11) targets: per-lane stopping makes lane costs diverge, and
under the monolithic loop a batch pays for its slowest lane on every
lane. Output rows:
``{"dataset", "mix", "batch", "p50_ms_per_query", "qps",
"speedup_vs_base"}``; ``__main__`` snapshots them to ``BENCH_latency.json``
(benchmarks/README.md).

``--workload`` (or :func:`run_workload_sweep`) measures the estimate-cache
serving path (DESIGN.md §12) instead: each :mod:`benchmarks.workloads`
scenario (zipfian repeats, drifting popularity, correlated tau bands,
mixed ingest+query) is served twice through the SAME coalescer harness —
once with the cache (``cache_size > 0``), once without (the PR 4 prober
path) — with the two sides alternated round-robin so ambient load biases
them equally. Reported per (scenario, side): queries/sec (median across
rounds), hit/stale rates, evictions, and meanQ q-error where ground truth
is valid (every scenario except ``mixed``, whose ingests change it).
``--smoke`` shrinks the corpus and stream for the CI hot-path regression
gate. Workload rows carry a ``"workload"`` key and are MERGED into
``BENCH_latency.json`` alongside the batch-sweep rows.
"""
from __future__ import annotations

import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import estimator as E

BATCH_SIZES = (1, 8, 64, 256)
SKEW_HEAVY_FRAC = 0.125     # fraction of large-tau requests in the skew mix


def run(datasets=None):
    rows = []
    for name in datasets or common.DATASETS:
        ds = common.dataset(name)
        d = ds.x.shape[1]
        for meth, fn in {
            "DynamicProber": lambda: common.eval_prober(
                ds, common.prober_cfg(False, d)),
            "DynamicProber-PQ": lambda: common.eval_prober(
                ds, common.prober_cfg(True, d)),
            "Sampling1%": lambda: common.eval_sampling(ds, 0.01),
            "MLP-lite": lambda: common.eval_mlp(ds),
        }.items():
            out = fn()
            rows.append({"dataset": name, "method": meth,
                         "ms_per_query": out["ms_per_query"]})
            print(f"[latency] {name:9s} {meth:16s} "
                  f"{out['ms_per_query']:8.2f} ms/query")
    return rows


def adaptive_cfg(cfg):
    """ε-faithful stopping for the skewed sweep (DESIGN.md §11).

    ``serve_cfg`` truncates EVERY lane at ``max_visit/chunk = 4`` slabs —
    a throughput trade made for the monolithic scheduler (a batch pays for
    its slowest lane, so the old loop capped the slowest lane) that also
    flattens per-lane cost to ~4 slabs regardless of the workload,
    suppressing the very skew a skew sweep must measure. The skew mix
    therefore restores the paper's adaptive stopping (full default visit
    budget, ring budget covering the ~2a/ε samples a PTF decision needs,
    fine-grained chunks) on BOTH sides of any A/B: lane costs then span
    ~13-55 slabs and the scheduler — not the truncation — decides the
    wall-clock. All three fields predate the compacting scheduler, so the
    same config drives older checkouts unchanged.
    """
    return cfg.replace(chunk=128, ring_budget=2048, max_visit=8192)


def _sweep_requests(ds, pool: int, mix: str):
    """(qs, taus) for one workload mix. ``uniform`` draws taus uniformly
    from the per-query radius grid; ``skew`` gives a ``SKEW_HEAVY_FRAC``
    minority the LARGEST grid radius (slow lanes: high selectivity needs
    many Chernoff samples) and everyone else the smallest (fast lanes:
    PTF after a slab or two) — shuffled so every batch holds the mix."""
    rng = np.random.default_rng(0)
    queries = np.asarray(ds.queries)
    taus_all = np.asarray(ds.taus)
    qi = rng.integers(0, queries.shape[0], pool)
    if mix == "uniform":
        ti = rng.integers(0, taus_all.shape[1], pool)
        taus = taus_all[qi, ti]
    else:
        assert mix == "skew", mix
        heavy = rng.permutation(pool) < max(int(pool * SKEW_HEAVY_FRAC), 1)
        taus = np.where(heavy, taus_all[qi, -1], taus_all[qi, 0])
    return jnp.asarray(queries[qi]), jnp.asarray(taus.astype(np.float32))


def run_batch_sweep(batch_sizes=BATCH_SIZES, dataset: str = "sift",
                    pool: int = 256, reps: int = 5,
                    mixes=("uniform", "skew")):
    """Throughput/latency of ``estimate_batch`` vs batch size Q, per mix.

    A fixed pool of ``pool`` (query, tau) requests is processed at every
    batch size — Q=1 is the per-request dispatch baseline, larger Q
    coalesces the same workload into pool/Q jitted steps — using the
    throughput-tuned :func:`common.serve_cfg`. Measurement rounds are
    INTERLEAVED across batch sizes so ambient load on a shared/throttled
    host biases every Q equally. Reported per (mix, Q): p50 per-query
    latency (median per-batch wall time / Q) and queries/sec (Q / MEAN
    batch time — on the bimodal skew mix, small-Q batches are themselves
    bimodal, so a median would report the fast-lane rate rather than
    sustained throughput); ``speedup_vs_base`` is relative to that mix's
    Q=1.
    """
    assert pool >= max(batch_sizes), \
        f"pool={pool} must cover the largest batch size {max(batch_sizes)}"
    ds = common.dataset(dataset)
    base_cfg = common.serve_cfg(ds.x.shape[1])
    # build is stopping-config independent, so both mixes share the state
    st = E.build(ds.x, base_cfg, jax.random.PRNGKey(0))
    jax.block_until_ready(st.index.order)
    rows = []
    for mix in mixes:
        cfg = adaptive_cfg(base_cfg) if mix == "skew" else base_cfg
        qs, taus = _sweep_requests(ds, pool, mix)
        for q in batch_sizes:                            # compile per shape
            E.estimate_batch(st, qs[:q], taus[:q], cfg,
                             jax.random.PRNGKey(0)).block_until_ready()
        times: dict[int, list[float]] = {q: [] for q in batch_sizes}
        for r in range(reps):
            for q in batch_sizes:
                for b in range(max(pool // q, 1)):
                    lo = b * q
                    t0 = time.perf_counter()
                    E.estimate_batch(st, qs[lo:lo + q], taus[lo:lo + q], cfg,
                                     jax.random.PRNGKey(r * pool + b)
                                     ).block_until_ready()
                    times[q].append(time.perf_counter() - t0)
        base_q, base_qps = batch_sizes[0], None
        for q in batch_sizes:
            p50 = float(np.percentile(times[q], 50))
            qps = q / float(np.mean(times[q]))
            base_qps = qps if base_qps is None else base_qps
            rows.append({"dataset": dataset, "mix": mix, "batch": q,
                         "p50_ms_per_query": 1e3 * p50 / q, "qps": qps,
                         "speedup_vs_base": qps / base_qps})
            print(f"[latency-batch] {dataset:9s} {mix:8s} Q={q:4d} "
                  f"{1e3 * p50 / q:8.3f} ms/query p50  {qps:10.1f} q/s  "
                  f"({qps / base_qps:5.2f}x vs Q={base_q})")
    return rows


def _serve_workload(wl, co, batch: int):
    """Serve one workload stream in arrival order through ``co`` —
    flushing every ``batch`` queries — and time it end to end (lookups,
    miss probes, write-backs and ingest application all included). The
    fresh side is the SAME harness with ``cache_size=0``, so an A/B
    compares exactly the cache partition/merge step plus the probe work it
    saves. Returns ``(qps, served)`` with ``served`` the
    ``[(pool_idx, CardRequest), ...]`` stream in arrival order."""
    served, pending = [], []
    t0 = time.perf_counter()
    for kind, payload in wl.events:
        if kind == "ingest":
            co.ingest(payload)          # applied before the next flush
            continue
        q, tau, _ = wl.request(payload)
        pending.append((payload, co.submit(q, tau)))
        if len(pending) >= batch:
            co.flush()
            served.extend(pending)
            pending = []
    if pending:
        co.flush()
        served.extend(pending)
    dt = time.perf_counter() - t0
    return len(served) / dt, served


def run_workload_sweep(dataset: str = "sift", scenarios=None,
                       n_events: int = 1024, batch: int = 64,
                       pool: int = 64, skew: float = 0.99, reps: int = 3,
                       cache_size: int = 1024, reuse_tol: float = 0.0,
                       smoke: bool = False):
    """Cached-vs-fresh serving A/B across the workload scenarios (module
    docstring). The acceptance gate this sweep measures: on ``zipf``
    (skew ~0.99, Q=``batch``) the cached side sustains >= 2x queries/sec
    at ``reuse_tol=0`` with meanQ unchanged (exact-repeat hits are
    bit-identical, so any meanQ delta is sampling noise between sides'
    PRNG keys, not cache error).

    Each side keeps ONE coalescer across rounds and runs the stream once
    UNTIMED first (compiles every flush shape and brings the cache to
    steady state — serving is a long-running process; cold-start compiles
    and compulsory misses are setup cost, not throughput), then ``reps``
    timed rounds with the side order alternated round-robin so ambient
    load on a throttled host biases both sides equally. Hit/stale/evict
    rates are computed over the timed rounds only."""
    from benchmarks import workloads
    from repro.core import updates as U
    from repro.data import vectors
    from repro.serve.engine import CardinalityCoalescer

    scenarios = tuple(scenarios or workloads.SCENARIOS)
    if smoke:
        n_events, pool, batch, reps = 128, 32, 16, 1
        cache_size = 256
        ds = vectors.load(dataset, n_queries=6, scale=0.05)
    else:
        ds = common.dataset(dataset)
    cfg = common.serve_cfg(ds.x.shape[1])
    key = jax.random.PRNGKey(0)
    n = ds.x.shape[0]
    rows = []
    for sc in scenarios:
        # per-scenario sizing: drift's popularity universe must EXCEED the
        # cache so the sliding window actually exercises CLOCK eviction;
        # tau-corr additionally runs a reuse_tol>0 side (the banding knob
        # is what that scenario exists to measure)
        sc_pool, sc_cache = pool, cache_size
        if sc == "drift":
            sc_pool, sc_cache = pool * 4, max(pool // 2, 16)
        wl = workloads.generate(ds, sc, n_events=n_events, pool=sc_pool,
                                skew=skew, seed=0,
                                ingest_every=32 if smoke else 128)
        sides = {"fresh": (0, 0.0), "cached": (sc_cache, reuse_tol)}
        if sc == "tau-corr":
            sides["cached-tol"] = (sc_cache, max(reuse_tol, 0.25))
        # mixed re-applies its ingest events on EVERY pass (warm + timed
        # rounds) — size the spare capacity for all of them (DESIGN.md §10)
        n_ingest = sum(e[1].shape[0] for e in wl.events if e[0] == "ingest")
        capacity = U.next_capacity(n, n + (reps + 1) * n_ingest) \
            if n_ingest else None
        state = E.build(ds.x, cfg, key, track_epochs=True,
                        capacity=capacity)
        jax.block_until_ready(state.index.order)
        cos = {side: CardinalityCoalescer(state, cfg, key, max_batch=batch,
                                          cache_size=cs, reuse_tol=tol)
               for side, (cs, tol) in sides.items()}
        for side in cos:                       # untimed warm pass
            _serve_workload(wl, cos[side], batch)
        stats0 = {side: dict(cos[side].cache_stats) for side in cos}
        qps: dict[str, list[float]] = {side: [] for side in cos}
        last = {}
        for r in range(reps):
            # alternate side order round-robin (throttled-host fairness)
            order = list(cos) if r % 2 == 0 else list(cos)[::-1]
            for side in order:
                q, served = _serve_workload(wl, cos[side], batch)
                qps[side].append(q)
                last[side] = served
        for side in cos:
            served = last[side]
            stats = {k: cos[side].cache_stats[k] - stats0[side][k]
                     for k in stats0[side]}
            qerrs = [common.qerror(req.est, wl.truth[pi])
                     for pi, req in served] if sc != "mixed" else None
            looked = max(stats["lookups"], 1)
            row = {"dataset": dataset, "workload": sc, "batch": batch,
                   "side": side, "reuse_tol": sides[side][1],
                   "n_events": len(served),
                   "qps": float(np.median(qps[side])),
                   "qps_rounds": [round(v, 1) for v in qps[side]],
                   "hit_rate": stats["hits"] / looked,
                   "stale_rate": stats["stale"] / looked,
                   "evicts": stats["evicts"],
                   "mean_qerror": float(np.mean(qerrs)) if qerrs else None}
            if side != "fresh":
                pairs = [c / f for c, f in zip(qps[side], qps["fresh"])]
                row["speedup_vs_fresh"] = float(np.median(pairs))
                row["speedup_rounds"] = [round(v, 2) for v in pairs]
            rows.append(row)
            print(f"[workload] {dataset:9s} {sc:8s} {side:10s} "
                  f"{row['qps']:9.1f} q/s  hit={row['hit_rate']:.2f} "
                  f"stale={row['stale_rate']:.2f} "
                  f"meanQ={row['mean_qerror'] if qerrs else float('nan'):.3f}"
                  + (f"  ({row['speedup_vs_fresh']:.2f}x vs fresh)"
                     if side != "fresh" else ""))
    return rows


if __name__ == "__main__":
    # distinct tags per sweep — the batch/skew rows are the longitudinal
    # scheduling record and must not be clobbered by a methods-only run;
    # workload rows share the latency tag but merge (carry a "workload"
    # key) instead of clobbering the batch rows, and vice versa
    args = sys.argv[1:]
    if "--workload" in args:
        rows = run_workload_sweep(smoke="--smoke" in args)
        if "--smoke" in args:       # CI gate: never clobber the committed
            pass                    # record with tiny-corpus numbers
        else:
            common.write_bench_json("latency", rows,
                                    meta={"sweep": ["workload"]},
                                    retain=lambda r: "workload" not in r)
    elif "--batch-sweep" in args:
        rows = run_batch_sweep()
        common.write_bench_json("latency", rows, meta={"sweep": ["batch"]},
                                retain=lambda r: "workload" in r)
    else:
        rows = run()
        common.write_bench_json("methods", rows, meta={"sweep": ["latency"]})
