"""Paper Fig. 5: error-tolerance eps — accuracy/latency trade-off, incl. the
turning point after which smaller eps stops helping."""
from __future__ import annotations

from benchmarks import common


def run(dataset: str = "sift", eps_grid=(0.1, 0.03, 0.01, 0.003, 0.001)):
    ds = common.dataset(dataset)
    d = ds.x.shape[1]
    rows = []
    for eps in eps_grid:
        out = common.eval_prober(ds, common.prober_cfg(False, d, eps=eps))
        rows.append({"eps": eps, "mean_qerror": out["stats"]["mean"],
                     "ms_per_query": out["ms_per_query"]})
        print(f"[eps] eps={eps:7.4f} meanQ={out['stats']['mean']:6.2f} "
              f"{out['ms_per_query']:7.2f} ms/query")
    return rows


if __name__ == "__main__":
    run()
