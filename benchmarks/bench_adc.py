"""Paper Fig. 4: ADC vs exact distance computation — speedup vs
dimensionality (paper: ~1.6x, growing with d). Each row also exercises the
batched full-ADC-scan baseline (``adc_scan_estimate_batch`` -> the batched
Pallas kernel, DESIGN.md §9) on a code subset — on CPU the kernel runs in
interpret mode, so ``t_scan8_ms`` is a correctness/wiring check there, not
a perf claim; the kernel's bandwidth story is for TPU."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import baselines, pq as pqmod
from repro.core.config import ProberConfig


def _time(fn, *args, reps=20):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        fn(*args).block_until_ready()
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / reps


def run(dims=(128, 304, 960, 1776), n: int = 20000):
    rows = []
    key = jax.random.PRNGKey(0)
    for d in dims:
        x = jax.random.normal(key, (n, d))
        q = x[0] + 0.1
        cfg = ProberConfig(pq_m=16, pq_kc=64, pq_iters=5)
        pq = pqmod.fit(x, cfg, key)
        lut = pqmod.adc_table(pq, q)

        exact = jax.jit(lambda xx, qq: jnp.sum((xx - qq[None]) ** 2, -1))
        adc = jax.jit(pqmod.adc_distance)
        t_exact = _time(exact, x, q)
        t_adc = _time(adc, lut, pq.codes)
        # batched multi-query scan through the Pallas kernel (Q=8, code
        # subset: interpret-mode execution on CPU is Python-speed)
        sub = pqmod.PQIndex(centroids=pq.centroids, codes=pq.codes[:2048],
                            counts=pq.counts, resid=pq.resid[:2048],
                            n_valid=jnp.int32(2048))
        qs8 = x[:8] + 0.1
        taus8 = jnp.full((8,), jnp.sqrt(jnp.mean(jnp.sum(x[:64] ** 2, -1))))
        t_scan = _time(baselines.adc_scan_estimate_batch, sub, qs8, taus8,
                       reps=3)
        rows.append({"dim": d, "t_exact_ms": 1e3 * t_exact,
                     "t_adc_ms": 1e3 * t_adc,
                     "t_scan8_ms": 1e3 * t_scan,
                     "speedup": t_exact / t_adc})
        print(f"[adc] d={d:5d} exact={1e3*t_exact:7.3f}ms "
              f"adc={1e3*t_adc:7.3f}ms speedup={t_exact/t_adc:5.2f}x "
              f"scan8={1e3*t_scan:7.1f}ms")
    return rows


if __name__ == "__main__":
    run()
