"""Paper Fig. 6/7 + Table 5: large-scale dynamic updates — plus the
DESIGN.md §10 amortized-streaming sweep.

Per dataset: 10% of the data builds the initial framework; the remaining
90% arrives as updates. We measure (a) one-shot update time vs a
from-scratch rebuild, (b) amortized incremental throughput (points/sec)
when the 90% streams through fixed-size chunks against the capacity-padded
recompile-free ingest step, (c) Q-error of the updated framework vs the
static build, (d) the learned baseline's degradation when its (frozen)
model is asked about the updated corpus — paper Table 5's failure mode.

``--stream`` (or ``stream_run()``) runs the acceptance sweep at N=64k:
amortized incremental points/sec vs the from-scratch alternative — a
rebuild after every chunk arrival, each at a NEW shape and therefore each
paying a fresh compile (exactly the growth cost the capacity-padded layout
avoids; DESIGN.md §10) — with post-update q-error side by side with a
fresh build over the same queries.
"""
from __future__ import annotations

import sys
import time

import jax

from benchmarks import common
from repro.core import baselines, estimator as E, updates
from repro.data import vectors as V


def _timed(fn):
    t0 = time.time()
    out = fn()
    jax.block_until_ready(out)
    return out, time.time() - t0


def _stream(state, x_stream, cfg, chunk):
    """Feed ``x_stream`` through fixed-size update chunks; returns the final
    state and the wall time spent updating (excluding the first, compiling
    chunk — amortized steady-state throughput)."""
    n = x_stream.shape[0]
    state, t_warm = _timed(lambda: E.update(state, x_stream[:chunk], cfg))
    t0 = time.time()
    for i in range(chunk, n, chunk):
        state = E.update(state, x_stream[i:i + chunk], cfg)
    jax.block_until_ready(state.index.order)
    return state, time.time() - t0, t_warm


def _qerr_stats(st, cfg, queries, taus, cards, stride=2):
    errs = []
    for qi in range(queries.shape[0]):
        for t in range(0, taus.shape[1], stride):
            est = E.estimate(st, queries[qi], taus[qi, t], cfg,
                             jax.random.PRNGKey(qi * 31 + t))
            errs.append(common.qerror(float(est), float(cards[qi, t])))
    return common.qerror_stats(errs)


def stream_run(n: int = 65536, dim: int = 32, chunk: int = 4096,
               n_queries: int = 6):
    """DESIGN.md §10 acceptance sweep: amortized incremental update
    throughput vs from-scratch rebuild at N=64k."""
    key = jax.random.PRNGKey(0)
    x = V.make_corpus(key, n, dim)
    cfg = common.prober_cfg(False, dim)
    n0 = max((n // 10) // chunk * chunk, chunk)

    # capacity-padded stream: 10% initial, the rest in fixed chunks. The
    # first chunk compiles the ingest step; every later chunk reuses it.
    st0, t_init = _timed(
        lambda: E.build(x[:n0], cfg, key, capacity=updates.next_pow2(n)))
    st_upd, t_stream, t_warm = _stream(st0, x[n0:], cfg, chunk)
    assert int(st_upd.n_valid) == n
    streamed = n - n0 - chunk
    pts_inc = streamed / max(t_stream, 1e-9)

    # the from-scratch alternative for the SAME arrival stream: rebuild the
    # whole index after each chunk. Every rebuild has a new point count, so
    # every rebuild pays a fresh trace+compile — that (not the sort) is the
    # growth cost the recompile-free path amortizes away.
    t_rebuild_total = 0.0
    for end in range(n0 + 2 * chunk, n + 1, chunk):
        _, dt = _timed(lambda: E.build(x[:end], cfg, key))
        t_rebuild_total += dt
    pts_reb = streamed / max(t_rebuild_total, 1e-9)

    # reference: one final-shape rebuild, cold then compile-cached
    _, t_rebuild_cold = _timed(lambda: E.build(x, cfg, key))
    st_static, t_rebuild_warm = _timed(lambda: E.build(x, cfg, key))

    qs, taus, cards = V.paper_query_workload(jax.random.PRNGKey(1), x,
                                             n_queries)
    s_upd = _qerr_stats(st_upd, cfg, qs, taus, cards)
    s_static = _qerr_stats(st_static, cfg, qs, taus, cards)

    row = {"n": n, "chunk": chunk,
           "t_stream_s": t_stream, "t_first_chunk_s": t_warm,
           "t_rebuild_per_chunk_total_s": t_rebuild_total,
           "t_rebuild_once_cold_s": t_rebuild_cold,
           "t_rebuild_once_warm_s": t_rebuild_warm,
           "pts_per_s_incremental": pts_inc,
           "pts_per_s_rebuild_per_chunk": pts_reb,
           "speedup_vs_rebuild": pts_inc / max(pts_reb, 1e-9),
           "qerr_updated_mean": s_upd["mean"],
           "qerr_updated_p90": s_upd["p90"],
           "qerr_static_mean": s_static["mean"],
           "qerr_static_p90": s_static["p90"]}
    print(f"[updates/stream] N={n} chunk={chunk} "
          f"inc={pts_inc:,.0f} pts/s | rebuild-per-chunk={pts_reb:,.0f} "
          f"pts/s | speedup {row['speedup_vs_rebuild']:.1f}x | "
          f"meanQ updated={s_upd['mean']:.2f} static={s_static['mean']:.2f}")
    return [row]


def run(datasets=("sift", "glove"), chunk: int = 1024):
    rows = []
    for name in datasets:
        ds = common.dataset(name)
        d = ds.x.shape[1]
        cfg = common.prober_cfg(False, d)
        n = ds.x.shape[0]
        n0 = max(int(n * 0.1) // 4 * 4, 4)
        key = jax.random.PRNGKey(0)

        t0 = time.time()
        st0 = E.build(ds.x[:n0], cfg, key,
                      capacity=updates.next_pow2(n))
        jax.block_until_ready(st0.index.order)
        t_init = time.time() - t0

        # one-shot 90% update (paper Fig. 6 setting)
        st_upd, t_update = _timed(lambda: E.update(st0, ds.x[n0:], cfg))

        _, t_rebuild = _timed(lambda: E.build(ds.x, cfg, key))
        st_static, t_rebuild_warm = _timed(lambda: E.build(ds.x, cfg, key))

        # amortized streaming throughput over the same 90% (fixed chunks,
        # recompile-free in-capacity steps — DESIGN.md §10); the reference
        # is ONE compile-cached rebuild at the final shape, i.e. the most
        # charitable possible rebuild number (--stream measures the honest
        # rebuild-per-chunk baseline)
        st_s, t_stream, _ = _stream(st0, ds.x[n0:], cfg, chunk)
        streamed = max(n - n0 - chunk, 1)
        pts_inc = streamed / max(t_stream, 1e-9)
        pts_reb = n / max(t_rebuild_warm, 1e-9)

        s_upd = _qerr_stats(st_upd, cfg, ds.queries, ds.taus, ds.cards)
        s_static = _qerr_stats(st_static, cfg, ds.queries, ds.taus, ds.cards)

        # learned baseline: trained on the initial 10%, frozen, asked about
        # the full corpus (paper Table 5's setting)
        q_init, t_init_, c_init = V.paper_query_workload(
            jax.random.PRNGKey(1), ds.x[:n0], ds.queries.shape[0])
        m = baselines.fit_mlp(ds.x[:n0], q_init, t_init_, c_init,
                              jax.random.PRNGKey(2))
        errs = []
        for qi in range(ds.queries.shape[0]):
            for t in range(0, ds.taus.shape[1], 2):
                est = float(baselines.mlp_estimate(m, ds.queries[qi],
                                                   ds.taus[qi, t]))
                errs.append(common.qerror(est, float(ds.cards[qi, t])))
        s_mlp = common.qerror_stats(errs)

        rows.append({"dataset": name, "t_init_s": t_init,
                     "t_update_s": t_update, "t_rebuild_s": t_rebuild,
                     "pts_per_s_incremental": pts_inc,
                     "pts_per_s_rebuild": pts_reb,
                     "qerr_updated_mean": s_upd["mean"],
                     "qerr_static_mean": s_static["mean"],
                     "qerr_mlp_frozen_mean": s_mlp["mean"]})
        print(f"[updates] {name:9s} init={t_init:5.2f}s "
              f"update={t_update:5.2f}s rebuild={t_rebuild:5.2f}s | "
              f"stream {pts_inc:,.0f} pts/s vs rebuild {pts_reb:,.0f} pts/s | "
              f"meanQ updated={s_upd['mean']:.2f} static={s_static['mean']:.2f} "
              f"mlp-frozen={s_mlp['mean']:.2f}")
    return rows


if __name__ == "__main__":
    if "--stream" in sys.argv:
        stream_run()
    else:
        run()
