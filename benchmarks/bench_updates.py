"""Paper Fig. 6/7 + Table 5: large-scale dynamic updates.

10% of the data builds the initial framework; the remaining 90% arrives as
an update. We measure (a) update time vs a from-scratch rebuild, (b) Q-error
of the updated framework vs the static build, (c) the learned baseline's
degradation when its (frozen) model is asked about the updated corpus —
paper Table 5's failure mode.
"""
from __future__ import annotations

import time

import jax

from benchmarks import common
from repro.core import baselines, estimator as E


def run(datasets=("sift", "glove")):
    rows = []
    for name in datasets:
        ds = common.dataset(name)
        d = ds.x.shape[1]
        cfg = common.prober_cfg(False, d)
        n = ds.x.shape[0]
        n0 = max(int(n * 0.1) // 4 * 4, 4)
        key = jax.random.PRNGKey(0)

        t0 = time.time()
        st0 = E.build(ds.x[:n0], cfg, key)
        jax.block_until_ready(st0.index.order)
        t_init = time.time() - t0

        t0 = time.time()
        st_upd = E.update(st0, ds.x[n0:], cfg)
        jax.block_until_ready(st_upd.index.order)
        t_update = time.time() - t0

        t0 = time.time()
        st_static = E.build(ds.x, cfg, key)
        jax.block_until_ready(st_static.index.order)
        t_rebuild = time.time() - t0

        def qerrs(st):
            errs = []
            for qi in range(ds.queries.shape[0]):
                for t in range(0, ds.taus.shape[1], 2):
                    est = E.estimate(st, ds.queries[qi], ds.taus[qi, t], cfg,
                                     jax.random.PRNGKey(qi * 31 + t))
                    errs.append(common.qerror(float(est),
                                              float(ds.cards[qi, t])))
            return common.qerror_stats(errs)

        s_upd = qerrs(st_upd)
        s_static = qerrs(st_static)

        # learned baseline: trained on the initial 10%, frozen, asked about
        # the full corpus (paper Table 5's setting)
        import dataclasses
        sub = dataclasses.replace(ds)  # same queries; labels vs full corpus
        from repro.data import vectors as V
        q_init, t_init_, c_init = V.paper_query_workload(
            jax.random.PRNGKey(1), ds.x[:n0], ds.queries.shape[0])
        m = baselines.fit_mlp(ds.x[:n0], q_init, t_init_, c_init,
                              jax.random.PRNGKey(2))
        errs = []
        for qi in range(ds.queries.shape[0]):
            for t in range(0, ds.taus.shape[1], 2):
                est = float(baselines.mlp_estimate(m, ds.queries[qi],
                                                   ds.taus[qi, t]))
                errs.append(common.qerror(est, float(ds.cards[qi, t])))
        s_mlp = common.qerror_stats(errs)

        rows.append({"dataset": name, "t_init_s": t_init,
                     "t_update_s": t_update, "t_rebuild_s": t_rebuild,
                     "qerr_updated_mean": s_upd["mean"],
                     "qerr_static_mean": s_static["mean"],
                     "qerr_mlp_frozen_mean": s_mlp["mean"]})
        print(f"[updates] {name:9s} init={t_init:5.2f}s "
              f"update={t_update:5.2f}s rebuild={t_rebuild:5.2f}s | "
              f"meanQ updated={s_upd['mean']:.2f} static={s_static['mean']:.2f} "
              f"mlp-frozen={s_mlp['mean']:.2f}")
    return rows


if __name__ == "__main__":
    run()
