"""Shared benchmark helpers: datasets at CPU scale, method registry,
Q-error statistics (paper §6.1), and the machine-readable ``BENCH_*.json``
trajectory snapshots (benchmarks/README.md)."""
from __future__ import annotations

import dataclasses
import json
import pathlib
import platform
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import baselines, estimator as E
from repro.core.config import ProberConfig
from repro.data import vectors

BENCH_SCALE = {"sift": 0.25, "glove": 0.25, "fasttext": 0.25,
               "gist": 0.25, "youtube": 0.25}
N_QUERIES = 10
DATASETS = list(vectors.CORPORA)

_CACHE: dict = {}


def dataset(name: str) -> vectors.VectorDataset:
    if name not in _CACHE:
        _CACHE[name] = vectors.load(name, n_queries=N_QUERIES,
                                    scale=BENCH_SCALE[name])
    return _CACHE[name]


def _pq_m(d: int) -> int:
    """Largest standard subspace count that divides the dimension."""
    return 32 if d % 32 == 0 else (30 if d % 30 == 0 else 16)


def prober_cfg(use_pq: bool = False, d: int = 128, eps: float = 0.01
               ) -> ProberConfig:
    m = _pq_m(d)
    return ProberConfig(n_tables=2, n_funcs=10, ring_budget=2048,
                        central_budget=2048, chunk=128, eps=eps,
                        use_pq=use_pq, pq_m=m, pq_kc=64, pq_iters=8,
                        pq_exact_rings=2)


def serve_cfg(d: int = 128) -> ProberConfig:
    """Throughput-tuned serving configuration (DESIGN.md §9/§11).

    Single hash table, 12 hash functions, full-ADC qualification (central
    bucket included, so an estimate never touches the float corpus — only
    the cache-resident byte codes), bounded visit budget. Versus
    :func:`prober_cfg` it trades some accuracy (mean q-error ~2.3 vs ~2.0
    on the sift surrogate) for ~4x lower single-query latency and a batched
    path that amortises: the bench_latency batch sweep measures >3x
    queries/sec at Q=64 vs Q=1 with this config on a 2-core CPU host.

    The quantized uint8 ADC LUT (``pq_int8_lut``, DESIGN.md §11) is turned
    on when the installed config supports it — guarded by field presence so
    this harness can also drive OLDER checkouts of the repo for A/B
    trajectory comparisons (the point of BENCH_*.json).
    """
    m = _pq_m(d)
    kw = dict(n_tables=1, n_funcs=12, ring_budget=1024,
              central_budget=512, chunk=512, max_visit=2048,
              use_pq=True, pq_m=m, pq_kc=64, pq_iters=8,
              pq_exact_rings=0, pq_exact_central=False)
    fields = {f.name for f in dataclasses.fields(ProberConfig)}
    if "pq_int8_lut" in fields:
        kw["pq_int8_lut"] = True
    return ProberConfig(**kw)


def write_bench_json(tag: str, rows: list, meta: dict | None = None,
                     retain=None):
    """Snapshot benchmark ``rows`` to ``BENCH_<tag>.json`` at the repo root
    — the machine-readable perf trajectory diffed across PRs
    (benchmarks/README.md). Returns the path written.

    ``retain`` (predicate over existing rows): rows of the current file it
    accepts are KEPT ahead of the new rows, and the old meta ``sweep`` list
    is merged. Sweeps sharing one tag use this so a standalone run of one
    sweep (e.g. ``bench_latency --workload``) never clobbers the other
    sweep's committed record in the same file.
    """
    path = pathlib.Path(__file__).resolve().parent.parent / \
        f"BENCH_{tag}.json"
    meta = dict(meta or {})
    kept: list = []
    if retain is not None and path.exists():
        old = json.loads(path.read_text())
        kept = [r for r in old.get("rows", []) if retain(r)]
        old_sweep = old.get("meta", {}).get("sweep", [])
        if kept and old_sweep:
            meta["sweep"] = sorted(set(old_sweep) | set(meta.get("sweep",
                                                                 [])))
    payload = {"meta": {"date": time.strftime("%Y-%m-%d"),
                        "backend": jax.default_backend(),
                        "device_count": jax.device_count(),
                        "platform": platform.platform(),
                        **meta},
               "rows": kept + rows}
    path.write_text(json.dumps(payload, indent=1) + "\n")
    print(f"[bench] wrote {path}")
    return path


def qerror(est: float, true: float) -> float:
    e, c = max(est, 1.0), max(true, 1.0)
    return max(e / c, c / e)


def qerror_stats(errs) -> dict:
    a = np.asarray(errs, dtype=np.float64)
    return {"mean": float(a.mean()),
            "p90": float(np.percentile(a, 90)),
            "p95": float(np.percentile(a, 95)),
            "p99": float(np.percentile(a, 99)),
            "max": float(a.max())}


def eval_prober(ds, cfg, key=None, return_time: bool = False):
    key = key if key is not None else jax.random.PRNGKey(0)
    t0 = time.time()
    st = E.build(ds.x, cfg, key)
    jax.block_until_ready(st.index.order)
    build_s = time.time() - t0
    errs, times = [], []
    nq, nt = ds.taus.shape
    for qi in range(nq):
        qs = jnp.tile(ds.queries[qi][None], (nt, 1))
        # warm compile once
        if qi == 0:
            E.estimate_batch(st, qs, ds.taus[qi], cfg,
                             jax.random.PRNGKey(0)).block_until_ready()
        t0 = time.time()
        ests = E.estimate_batch(st, qs, ds.taus[qi], cfg,
                                jax.random.PRNGKey(qi))
        ests.block_until_ready()
        times.append((time.time() - t0) / nt)
        for t in range(nt):
            errs.append(qerror(float(ests[t]), float(ds.cards[qi, t])))
    out = {"errs": errs, "stats": qerror_stats(errs), "build_s": build_s,
           "ms_per_query": 1e3 * float(np.mean(times))}
    return out


def eval_sampling(ds, rate: float = 0.01):
    n = ds.x.shape[0]
    ns = max(int(n * rate), 1)
    errs, times = [], []
    nq, nt = ds.taus.shape
    baselines.sampling_estimate(ds.x, ds.queries[0], ds.taus[0, 0],
                                jax.random.PRNGKey(0), ns).block_until_ready()
    for qi in range(nq):
        t0 = time.time()
        for t in range(nt):
            est = baselines.sampling_estimate(
                ds.x, ds.queries[qi], ds.taus[qi, t],
                jax.random.PRNGKey(qi * 100 + t), ns)
            errs.append(qerror(float(est), float(ds.cards[qi, t])))
        times.append((time.time() - t0) / nt)
    return {"errs": errs, "stats": qerror_stats(errs),
            "ms_per_query": 1e3 * float(np.mean(times))}


def eval_mlp(ds, key=None, train_frac: float = 0.6):
    """Train the learned baseline on held-out queries, eval on the rest."""
    key = key if key is not None else jax.random.PRNGKey(0)
    nq = ds.queries.shape[0]
    ntr = max(int(nq * train_frac), 1)
    t0 = time.time()
    m = baselines.fit_mlp(ds.x, ds.queries[:ntr], ds.taus[:ntr],
                          ds.cards[:ntr], key)
    train_s = time.time() - t0
    errs, times = [], []
    for qi in range(ntr, nq):
        t0 = time.time()
        for t in range(ds.taus.shape[1]):
            est = float(baselines.mlp_estimate(m, ds.queries[qi],
                                               ds.taus[qi, t]))
            errs.append(qerror(est, float(ds.cards[qi, t])))
        times.append((time.time() - t0) / ds.taus.shape[1])
    return {"errs": errs, "stats": qerror_stats(errs), "build_s": train_s,
            "ms_per_query": 1e3 * float(np.mean(times)), "model": m}
