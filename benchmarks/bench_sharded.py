"""DESIGN.md §4 acceptance sweep: sharded dynamic serving on forced host
devices — incremental-ingest throughput and q-error vs shard count, with
both distributed stopping modes (local vs sync) side by side.

Per shard count S: build the capacity-padded sharded index on 10% of an
N=64k corpus, stream the remaining 90% through fixed-size chunks routed
round-robin to the shards (ONE jitted shard_map ingest step per chunk,
recompile-free in capacity — DESIGN.md §10 extended to the sharded index),
then measure estimation q-error through ``estimate_sharded`` in ``local``
and ``sync`` mode. S=1 is the plain single-device capacity-padded path
(PR-2's bench_updates stream), giving the in-process reference the sharded
aggregates and q-errors are compared against.

Standalone (forces its own XLA host device count, so not part of
``benchmarks.run``'s in-process suite):

  PYTHONPATH=src python -m benchmarks.bench_sharded          # sweep 1,2,4,8
  PYTHONPATH=src python -m benchmarks.bench_sharded --quick  # 1 and 8 only
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import sys                                              # noqa: E402
import time                                             # noqa: E402

import jax                                              # noqa: E402
import jax.numpy as jnp                                 # noqa: E402
import numpy as np                                      # noqa: E402

from benchmarks import common                           # noqa: E402
from repro import compat                                # noqa: E402
from repro.core import distributed as D, estimator as E, updates  # noqa: E402
from repro.data import vectors as V                     # noqa: E402


def _stream_single(x, cfg, key, n0, chunk):
    """S=1 reference: the plain capacity-padded single-device stream."""
    st = E.build(x[:n0], cfg, key, capacity=updates.next_pow2(x.shape[0]))
    jax.block_until_ready(st.index.order)
    t0 = time.time()
    st = E.update(st, x[n0:n0 + chunk], cfg)            # compiling chunk
    jax.block_until_ready(st.index.order)
    t_warm = time.time() - t0
    t0 = time.time()
    for i in range(n0 + chunk, x.shape[0], chunk):
        st = E.update(st, x[i:i + chunk], cfg)
    jax.block_until_ready(st.index.order)
    return st, time.time() - t0, t_warm


def _stream_sharded(x, cfg, key, n0, chunk, mesh):
    st, _ = D.build_sharded(x[:n0], cfg, key, mesh,
                            capacity=updates.next_pow2(x.shape[0]))
    jax.block_until_ready(st.index.order)
    x_np = np.asarray(x)
    t0 = time.time()
    st, nv = D.update_sharded(st, x_np[n0:n0 + chunk], cfg, mesh)
    jax.block_until_ready(st.index.order)
    t_warm = time.time() - t0
    t0 = time.time()
    for i in range(n0 + chunk, x.shape[0], chunk):
        st, nv = D.update_sharded(st, x_np[i:i + chunk], cfg, mesh,
                                  n_valid=nv)
    jax.block_until_ready(st.index.order)
    return st, time.time() - t0, t_warm


def _qerr_single(st, cfg, queries, taus, cards, key, stride=2):
    errs = []
    for qi in range(queries.shape[0]):
        cols = list(range(0, taus.shape[1], stride))
        qrep = jnp.tile(queries[qi][None], (len(cols), 1))
        ests = E.estimate_batch(st, qrep, taus[qi, jnp.asarray(cols)], cfg,
                                jax.random.fold_in(key, qi))
        errs += [common.qerror(float(ests[j]), float(cards[qi, t]))
                 for j, t in enumerate(cols)]
    return common.qerror_stats(errs)


def _qerr_sharded(st, cfg, queries, taus, cards, key, mesh, mode, stride=2):
    errs = []
    for qi in range(queries.shape[0]):
        cols = list(range(0, taus.shape[1], stride))
        qrep = jnp.tile(queries[qi][None], (len(cols), 1))
        ests = D.estimate_sharded(st, qrep, taus[qi, jnp.asarray(cols)], cfg,
                                  jax.random.fold_in(key, qi), mesh,
                                  mode=mode)
        errs += [common.qerror(float(ests[j]), float(cards[qi, t]))
                 for j, t in enumerate(cols)]
    return common.qerror_stats(errs)


def run(n: int = 65536, dim: int = 32, chunk: int = 4096,
        n_queries: int = 6, shard_counts=(1, 2, 4, 8)):
    key = jax.random.PRNGKey(0)
    x = V.make_corpus(key, n, dim)
    cfg = common.prober_cfg(False, dim)
    n0 = max((n // 10) // chunk * chunk, chunk)
    streamed = n - n0 - chunk            # excludes the compiling first chunk
    qs, taus, cards = V.paper_query_workload(jax.random.PRNGKey(1), x,
                                             n_queries)
    avail = len(jax.devices())
    rows = []
    for s in shard_counts:
        if s > avail:
            print(f"[sharded] skip S={s}: only {avail} devices")
            continue
        if s == 1:
            st, t_stream, t_warm = _stream_single(x, cfg, key, n0, chunk)
            assert int(jax.device_get(st.index.n_valid)) == n
            q_local = q_sync = _qerr_single(st, cfg, qs, taus, cards, key)
        else:
            mesh = compat.make_mesh((s,), ("data",),
                                    devices=jax.devices()[:s])
            st, t_stream, t_warm = _stream_sharded(x, cfg, key, n0, chunk,
                                                   mesh)
            nv = np.asarray(jax.device_get(st.index.n_valid))
            assert int(nv.sum()) == n, nv
            q_local = _qerr_sharded(st, cfg, qs, taus, cards, key, mesh,
                                    "local")
            q_sync = _qerr_sharded(st, cfg, qs, taus, cards, key, mesh,
                                   "sync")
        pts = streamed / max(t_stream, 1e-9)
        rows.append({"shards": s, "n": n, "chunk": chunk,
                     "t_stream_s": t_stream, "t_first_chunk_s": t_warm,
                     "pts_per_s_ingest": pts,
                     "qerr_local_mean": q_local["mean"],
                     "qerr_local_p90": q_local["p90"],
                     "qerr_sync_mean": q_sync["mean"],
                     "qerr_sync_p90": q_sync["p90"]})
        print(f"[sharded] S={s} ingest={pts:,.0f} pts/s "
              f"(first-chunk {t_warm:.2f}s) | meanQ local="
              f"{q_local['mean']:.3f} sync={q_sync['mean']:.3f}")
    base = rows[0]
    for r in rows[1:]:
        r["ingest_speedup_vs_1dev"] = \
            r["pts_per_s_ingest"] / max(base["pts_per_s_ingest"], 1e-9)
        r["qerr_local_vs_1dev"] = \
            r["qerr_local_mean"] / max(base["qerr_local_mean"], 1e-9)
    if len(rows) > 1:
        last = rows[-1]
        print(f"[sharded] S={last['shards']} vs single-device: ingest "
              f"{last['ingest_speedup_vs_1dev']:.2f}x, meanQ ratio "
              f"{last['qerr_local_vs_1dev']:.3f}")
    return rows


if __name__ == "__main__":
    if "--quick" in sys.argv:
        run(shard_counts=(1, 8))
    else:
        run()
