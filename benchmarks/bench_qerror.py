"""Paper Table 3: Q-error distribution per dataset × method."""
from __future__ import annotations

import jax

from benchmarks import common


def run(datasets=None):
    rows = []
    for name in datasets or common.DATASETS:
        ds = common.dataset(name)
        d = ds.x.shape[1]
        methods = {
            "DynamicProber": lambda: common.eval_prober(
                ds, common.prober_cfg(False, d)),
            "DynamicProber-PQ": lambda: common.eval_prober(
                ds, common.prober_cfg(True, d)),
            "Sampling1%": lambda: common.eval_sampling(ds, 0.01),
            "MLP-lite": lambda: common.eval_mlp(ds),
        }
        for meth, fn in methods.items():
            out = fn()
            s = out["stats"]
            rows.append({"dataset": name, "method": meth, **s})
            print(f"[qerror] {name:9s} {meth:16s} mean={s['mean']:7.2f} "
                  f"p90={s['p90']:7.2f} p95={s['p95']:7.2f} "
                  f"p99={s['p99']:8.2f} max={s['max']:9.2f}")
    return rows


if __name__ == "__main__":
    run()
