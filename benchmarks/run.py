"""Benchmark driver — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (plus human-readable progress
lines prefixed with [tag]) and snapshots the latency / q-error sections to
machine-readable JSON at the repo root — the perf trajectory diffed across
PRs (benchmarks/README.md). The committed record is three files:
``BENCH_latency.json`` (the batch/skew scheduling sweep + the workload
cache sweep), ``BENCH_methods.json`` (per-method latency) and
``BENCH_qerror.json`` (accuracy). A selected section that fails to produce
its documented snapshot is a hard error — the committed record must never
silently go missing.

  PYTHONPATH=src python -m benchmarks.run             # everything
  PYTHONPATH=src python -m benchmarks.run qerror adc  # a subset
"""
from __future__ import annotations

import sys

# section name -> the BENCH_<tag>.json snapshot it is documented to write
SNAPSHOT_TAGS = {"latency": "methods", "batch": "latency",
                 "workload": "latency", "qerror": "qerror"}


def main() -> None:
    which = set(sys.argv[1:]) or {"qerror", "latency", "batch", "workload",
                                  "build", "adc", "epsilon", "updates",
                                  "roofline"}
    csv: list[tuple[str, float, str]] = []
    method_rows: list[dict] = []
    batch_rows: list[dict] = []
    workload_rows: list[dict] = []
    qerror_rows: list[dict] = []

    if "qerror" in which:
        from benchmarks import bench_qerror
        for r in bench_qerror.run():
            qerror_rows.append(r)
            csv.append((f"qerror/{r['dataset']}/{r['method']}", 0.0,
                        f"meanQ={r['mean']:.3f};p90={r['p90']:.3f};"
                        f"p99={r['p99']:.3f};max={r['max']:.3f}"))
    if "latency" in which:
        from benchmarks import bench_latency
        for r in bench_latency.run():
            method_rows.append(r)
            csv.append((f"latency/{r['dataset']}/{r['method']}",
                        1e3 * r["ms_per_query"], "online-estimate"))
    if "batch" in which:
        from benchmarks import bench_latency
        for r in bench_latency.run_batch_sweep():
            batch_rows.append(r)
            csv.append((f"latency-batch/{r['dataset']}/"
                        f"{r.get('mix', 'uniform')}/Q{r['batch']}",
                        1e3 * r["p50_ms_per_query"],
                        f"qps={r['qps']:.0f};"
                        f"speedup={r['speedup_vs_base']:.2f}x"))
    if "workload" in which:
        from benchmarks import bench_latency
        for r in bench_latency.run_workload_sweep():
            workload_rows.append(r)
            extra = f";speedup={r['speedup_vs_fresh']:.2f}x" \
                if "speedup_vs_fresh" in r else ""
            csv.append((f"workload/{r['dataset']}/{r['workload']}/"
                        f"{r['side']}", 0.0,
                        f"qps={r['qps']:.0f};hit={r['hit_rate']:.2f}"
                        + extra))
    if "build" in which:
        from benchmarks import bench_build
        for r in bench_build.run():
            csv.append((f"build/{r['dataset']}", 0.0,
                        f"lsh={r['lsh_s']:.2f}s;table={r['table_s']:.2f}s;"
                        f"pq={r['pq_s']:.2f}s;mlp={r['mlp_train_s']:.2f}s"))
    if "adc" in which:
        from benchmarks import bench_adc
        for r in bench_adc.run():
            csv.append((f"adc/d{r['dim']}", 1e3 * r["t_adc_ms"],
                        f"speedup={r['speedup']:.2f}x"))
    if "epsilon" in which:
        from benchmarks import bench_epsilon
        for r in bench_epsilon.run():
            csv.append((f"epsilon/{r['eps']}", 1e3 * r["ms_per_query"],
                        f"meanQ={r['mean_qerror']:.3f}"))
    if "updates" in which:
        from benchmarks import bench_updates
        for r in bench_updates.run():
            csv.append((f"updates/{r['dataset']}", 1e6 * r["t_update_s"],
                        f"updatedQ={r['qerr_updated_mean']:.2f};"
                        f"staticQ={r['qerr_static_mean']:.2f};"
                        f"mlpFrozenQ={r['qerr_mlp_frozen_mean']:.2f};"
                        f"rebuild_s={r['t_rebuild_s']:.2f}"))
    if "roofline" in which:
        from pathlib import Path

        from benchmarks import bench_roofline
        variants = [("baseline", "results/dryrun")]
        if Path("results/dryrun_opt").exists():
            variants.append(("optimized", "results/dryrun_opt"))
        for tag, d in variants:
            for mesh in ("single", "multi"):
                for r in bench_roofline.run(d, mesh=mesh):
                    name = f"roofline-{tag}/{r['arch']}/{r['shape']}/{mesh}"
                    if "skipped" in r:
                        csv.append((name, 0.0, "skipped"))
                    else:
                        csv.append((name,
                                    1e6 * max(r["t_compute"], r["t_memory"],
                                              r["t_collective"]),
                                    f"dominant={r['dominant']};"
                                    f"useful={r['useful_ratio']:.2f};"
                                    f"mfu_bound={r['mfu_bound']:.3f};"
                                    f"peak_gib={r['peak_gib']:.2f}"))

    # distinct tags per sweep so a subset run never clobbers another sweep's
    # committed record: BENCH_latency.json = the batch/skew scheduling sweep
    # + the workload cache sweep (merged rows; workload rows carry a
    # "workload" key), BENCH_methods.json = per-method latency,
    # BENCH_qerror.json = accuracy
    from benchmarks import common
    written: set[str] = set()
    if method_rows:
        common.write_bench_json("methods", method_rows,
                                meta={"sweep": ["latency"]})
        written.add("methods")
    latency_meta = {"sweep": [s for s, rs in
                              (("batch", batch_rows),
                               ("workload", workload_rows)) if rs]}
    if batch_rows and workload_rows:
        common.write_bench_json("latency", batch_rows + workload_rows,
                                meta=latency_meta)
    elif batch_rows:
        common.write_bench_json("latency", batch_rows, meta=latency_meta,
                                retain=lambda r: "workload" in r)
    elif workload_rows:
        common.write_bench_json("latency", workload_rows, meta=latency_meta,
                                retain=lambda r: "workload" not in r)
    if batch_rows or workload_rows:
        written.add("latency")
    if qerror_rows:
        common.write_bench_json("qerror", qerror_rows)
        written.add("qerror")

    # fail LOUDLY if a selected section did not produce its documented
    # snapshot — a silently missing BENCH_*.json breaks the cross-PR
    # trajectory record this driver exists to maintain
    missing = {f"{sec} -> BENCH_{tag}.json"
               for sec, tag in SNAPSHOT_TAGS.items()
               if sec in which and tag not in written}
    if missing:
        raise SystemExit("documented benchmark snapshots were not written: "
                         + ", ".join(sorted(missing)))

    print("\nname,us_per_call,derived")
    for name, us, derived in csv:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
