"""Serving workload generator (DESIGN.md §12) — repeat-heavy request
streams for the estimate-cache benchmarks and any future serving sweep.

Real estimation traffic from many concurrent clients is not i.i.d.: a few
(query, tau) pairs dominate (zipfian repeats, the qwLSH observation), the
popular set drifts over time, each client sticks to a narrow tau band, and
queries interleave with corpus ingest. Each scenario here produces a
seeded, fully deterministic event stream over a pool of (query, tau)
requests drawn from the dataset's paper-protocol workload grid (so exact
cardinalities are known and q-error is measurable):

* ``zipf``     — stationary zipfian repeats over a shuffled pool
                 (``skew ~ 0.99``): the pure reuse regime the cache's
                 2x queries/sec acceptance gate is measured on.
* ``drift``    — the zipfian pool slides a window over the pool every
                 ``phase_len`` events: popularity is non-stationary, so a
                 cache must evict yesterday's heads (exercises CLOCK).
* ``tau-corr`` — each distinct query draws from its OWN small band of
                 adjacent grid taus (clients have characteristic
                 selectivities): hit rate then depends on tau banding, the
                 ``reuse_tol`` trade.
* ``mixed``    — zipfian queries interleaved with corpus ingest batches
                 every ``ingest_every`` queries: exercises epoch
                 invalidation under live updates (and is the zero-stale
                 correctness stream in tests/test_cache.py).

Events are ``("q", pool_index)`` / ``("ingest", (P_i, d) array)`` tuples;
:func:`Workload.request` resolves a pool index to its (q, tau, truth).
Everything derives from ``numpy.random.default_rng(seed)`` — the same
(scenario, seed, sizes) always yields the same stream, which is what makes
paired A/B comparisons (cached vs fresh serving on the SAME stream) fair.
"""
from __future__ import annotations

import dataclasses

import numpy as np

SCENARIOS = ("zipf", "drift", "tau-corr", "mixed")


@dataclasses.dataclass(frozen=True)
class Workload:
    """One generated request stream. ``truth`` holds exact cardinalities at
    GENERATION time — valid for q-error only while no ingest event has been
    applied (the ``mixed`` scenario measures hit rate / staleness, not
    q-error)."""
    name: str
    events: tuple            # (("q", pool_idx) | ("ingest", np.ndarray), ...)
    qs: np.ndarray           # (P, d) pool queries
    taus: np.ndarray         # (P,) pool radii
    truth: np.ndarray        # (P,) exact |{p : ||p - q|| <= tau}|

    def request(self, pool_idx: int):
        return self.qs[pool_idx], float(self.taus[pool_idx]), \
            float(self.truth[pool_idx])

    @property
    def n_queries(self) -> int:
        return sum(1 for kind, _ in self.events if kind == "q")


def _zipf_probs(pool: int, skew: float) -> np.ndarray:
    p = 1.0 / np.arange(1, pool + 1, dtype=np.float64) ** skew
    return p / p.sum()


def _request_pool(ds, pool: int, rng) -> tuple[np.ndarray, ...]:
    """Sample ``pool`` distinct (query, tau) pairs from the dataset's
    paper-protocol grid (vectors.paper_query_workload), rank-shuffled so
    zipf popularity is independent of grid position."""
    queries = np.asarray(ds.queries)
    taus = np.asarray(ds.taus)
    cards = np.asarray(ds.cards)
    nq, nt = taus.shape
    pairs = rng.permutation(nq * nt)[:pool]
    qi, ti = pairs // nt, pairs % nt
    return (queries[qi].astype(np.float32), taus[qi, ti].astype(np.float32),
            cards[qi, ti].astype(np.float32), qi)


def _ingest_batch(ds, rng, n: int, noise: float = 0.05) -> np.ndarray:
    """New corpus points near existing ones (in-distribution growth — the
    paper's §5 scenario): anchor on random live points + small noise."""
    x = np.asarray(ds.x)
    anchors = x[rng.integers(0, x.shape[0], n)]
    return (anchors + noise * rng.standard_normal(anchors.shape)
            ).astype(np.float32)


def generate(ds, scenario: str, n_events: int = 1024, pool: int = 64,
             skew: float = 0.99, seed: int = 0, phase_len: int = 256,
             drift_window: int | None = None, tau_band: int = 2,
             ingest_every: int = 128, ingest_n: int = 32) -> Workload:
    """Build one scenario's event stream (module docstring has the zoo).

    ``pool`` bounds the distinct (query, tau) pairs in play; ``skew`` is
    the zipf exponent (1.0 > skew > 0: heavier head for larger skew);
    ``phase_len``/``drift_window`` shape the ``drift`` scenario's
    popularity churn; ``tau_band`` is how many adjacent grid radii a
    ``tau-corr`` client wanders over; ``ingest_every``/``ingest_n`` pace
    the ``mixed`` scenario's update stream.
    """
    assert scenario in SCENARIOS, (scenario, SCENARIOS)
    rng = np.random.default_rng(seed)
    qs, taus, truth, qi = _request_pool(ds, pool, rng)
    pool = qs.shape[0]                      # may clip to the grid size

    if scenario == "tau-corr":
        # re-pool over DISTINCT queries: each client query owns one band of
        # `tau_band` ADJACENT grid radii; the stream zipfs over queries and
        # picks uniformly inside the query's own band
        taus_all = np.asarray(ds.taus)
        cards_all = np.asarray(ds.cards)
        queries = np.asarray(ds.queries)
        nq, nt = taus_all.shape
        pool_q = min(pool, nq)
        sel = rng.permutation(nq)[:pool_q]
        base = rng.integers(0, nt - tau_band + 1, pool_q)
        ids, new_taus, new_truth = [], [], []
        for i in range(pool_q):
            for b in range(tau_band):
                ids.append(sel[i])
                new_taus.append(taus_all[sel[i], base[i] + b])
                new_truth.append(cards_all[sel[i], base[i] + b])
        qs = queries[np.asarray(ids)].astype(np.float32)
        taus = np.asarray(new_taus, np.float32)
        truth = np.asarray(new_truth, np.float32)
        probs = _zipf_probs(pool_q, skew)
        heads = rng.choice(pool_q, size=n_events, p=probs)
        bands = rng.integers(0, tau_band, n_events)
        events = tuple(("q", int(h * tau_band + b))
                       for h, b in zip(heads, bands))
        return Workload("tau-corr", events, qs, taus, truth)

    if scenario == "drift":
        window = drift_window or max(pool // 4, 8)
        probs = _zipf_probs(window, skew)
        events = []
        for t in range(n_events):
            start = (t // phase_len) * max(window // 2, 1)
            events.append(("q", int((start + rng.choice(window, p=probs))
                               % pool)))
        return Workload("drift", tuple(events), qs, taus, truth)

    probs = _zipf_probs(pool, skew)
    picks = rng.choice(pool, size=n_events, p=probs)
    if scenario == "zipf":
        return Workload("zipf", tuple(("q", int(i)) for i in picks),
                        qs, taus, truth)

    # mixed: zipf queries + an ingest batch every `ingest_every` queries
    events: list = []
    for t, i in enumerate(picks):
        if t and t % ingest_every == 0:
            events.append(("ingest", _ingest_batch(ds, rng, ingest_n)))
        events.append(("q", int(i)))
    return Workload("mixed", tuple(events), qs, taus, truth)
