"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline)."""
from __future__ import annotations

import json
from pathlib import Path

DEFAULT_DIR = Path("results/dryrun")


def run(dry_dir: Path | str = DEFAULT_DIR, mesh: str = "single"):
    rows = []
    for p in sorted(Path(dry_dir).glob(f"*__{mesh}.json")):
        rec = json.loads(p.read_text())
        if "skipped" in rec:
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "skipped": rec["skipped"]})
            continue
        r = rec["roofline"]
        rows.append({
            "arch": rec["arch"], "shape": rec["shape"],
            "t_compute": r["t_compute_s"], "t_memory": r["t_memory_s"],
            "t_collective": r["t_collective_s"], "dominant": r["dominant"],
            "useful_ratio": r["useful_ratio"], "mfu_bound": r["mfu_bound"],
            "peak_gib": rec["memory"]["peak_memory_in_bytes"] / 2 ** 30,
        })
        print(f"[roofline] {rec['arch']:22s} {rec['shape']:12s} "
              f"dom={r['dominant']:10s} "
              f"t=({r['t_compute_s']:.2e},{r['t_memory_s']:.2e},"
              f"{r['t_collective_s']:.2e})s useful={r['useful_ratio']:.2f} "
              f"mfu<={r['mfu_bound']:.3f}")
    return rows


if __name__ == "__main__":
    run()
