"""Per-kernel allclose vs the pure-jnp oracles, swept over shapes/dtypes
(interpret=True executes the Pallas kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.kernels import ops, ref


@pytest.mark.parametrize("n,d,f", [(64, 16, 8), (300, 96, 20), (257, 33, 13),
                                   (1, 8, 4)])
def test_lsh_hash_matches_ref(n, d, f):
    ks = jax.random.split(jax.random.PRNGKey(n + d), 4)
    x = jax.random.normal(ks[0], (n, d))
    a = jax.random.normal(ks[1], (d, f))
    b = jax.random.uniform(ks[2], (f,))
    w = jax.random.uniform(ks[3], (f,), minval=0.5, maxval=2.0)
    np.testing.assert_array_equal(np.asarray(ops.lsh_hash(x, a, b, w)),
                                  np.asarray(ref.lsh_hash(x, a, b, w)))


@pytest.mark.parametrize("n,q,d", [(128, 16, 32), (251, 7, 64), (64, 1, 128),
                                   (1, 1, 8)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_l2dist_matches_ref(n, q, d, dtype):
    k1, k2 = jax.random.split(jax.random.PRNGKey(n * d))
    x = jax.random.normal(k1, (n, d), dtype)
    qq = jax.random.normal(k2, (q, d), dtype)
    got = np.asarray(ops.l2dist(x, qq))
    want = np.asarray(ref.l2dist(x.astype(jnp.float32),
                                 qq.astype(jnp.float32)))
    tol = 1e-3 if dtype == jnp.float32 else 0.15
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol * d)


@pytest.mark.parametrize("n,m,kc", [(100, 8, 16), (513, 16, 64), (1, 4, 8),
                                    (1024, 32, 256)])
def test_adc_matches_ref(n, m, kc):
    k1, k2 = jax.random.split(jax.random.PRNGKey(n + m))
    codes = jax.random.randint(k1, (n, m), 0, kc)
    lut = jax.random.uniform(k2, (m, kc))
    np.testing.assert_allclose(np.asarray(ops.adc(codes, lut)),
                               np.asarray(ref.adc(codes, lut)), rtol=1e-5)


@pytest.mark.parametrize("b,k", [(64, 6), (1000, 14), (3, 1), (2048, 10)])
def test_hamming_matches_ref(b, k):
    k1, k2 = jax.random.split(jax.random.PRNGKey(b + k))
    bc = jax.random.randint(k1, (b, k), -3, 4)
    qc = jax.random.randint(k2, (k,), -3, 4)
    np.testing.assert_array_equal(np.asarray(ops.hamming(bc, qc)),
                                  np.asarray(ref.hamming(bc, qc)))


@settings(max_examples=15, deadline=None)
@given(n=st.integers(1, 200), m=st.sampled_from([2, 4, 8]),
       kc=st.sampled_from([4, 16]), seed=st.integers(0, 99))
def test_adc_property_sweep(n, m, kc, seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    codes = jax.random.randint(k1, (n, m), 0, kc)
    lut = jax.random.uniform(k2, (m, kc))
    np.testing.assert_allclose(np.asarray(ops.adc(codes, lut, bn=64)),
                               np.asarray(ref.adc(codes, lut)), rtol=1e-5)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(1, 150), d=st.sampled_from([4, 32]),
       f=st.integers(1, 24), seed=st.integers(0, 99))
def test_lsh_hash_property_sweep(n, d, f, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    x = jax.random.normal(ks[0], (n, d))
    a = jax.random.normal(ks[1], (d, f))
    b = jax.random.uniform(ks[2], (f,))
    w = jax.random.uniform(ks[3], (f,), minval=0.5, maxval=2.0)
    np.testing.assert_array_equal(
        np.asarray(ops.lsh_hash(x, a, b, w, bn=64, bf=8)),
        np.asarray(ref.lsh_hash(x, a, b, w)))
