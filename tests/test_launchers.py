"""Launcher-level integration: the serve driver end-to-end, dry-run cell
spec construction for every (arch × shape), and distributed-estimator spec
plumbing."""
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.launch import specs as S


def test_serve_driver_end_to_end():
    from repro.launch import serve
    served, refused = serve.main([
        "--arch", "qwen2-7b", "--scale", "smoke", "--requests", "4",
        "--corpus", "1000", "--emb-dim", "32", "--max-calls", "16",
        "--slots", "2", "--max-len", "48",
    ])
    assert served >= 1
    assert refused >= 1          # the oversized operator must be refused


@pytest.mark.parametrize("arch", configs.ARCHS)
@pytest.mark.parametrize("shape", list(S.SHAPES))
def test_input_specs_constructible(arch, shape):
    """Every supported (arch x shape) cell yields well-formed abstract
    inputs: batch dims match the grid, dtypes are ints/floats as expected."""
    cfg = configs.get_config(arch)
    ok, why = S.cell_supported(cfg, shape)
    if not ok:
        assert "sub-quadratic" in why
        return
    batch = S.batch_specs_for(cfg, shape)
    info = S.SHAPES[shape]
    for name, leaf in batch.items():
        assert leaf.shape[0] == info["batch"], (name, leaf.shape)
        if name in ("tokens", "labels"):
            assert leaf.dtype == jnp.int32
    if info["kind"] == "decode":
        cache = S.cache_specs_for(cfg, shape)
        leaves = jax.tree_util.tree_leaves(cache)
        assert leaves, "decode cell must have a cache"
        # cache batch dim must match the grid
        big = [l for l in leaves if l.ndim >= 2]
        assert all(l.shape[1] == info["batch"] for l in big)


def test_param_specs_abstract_no_alloc():
    """param_specs_for must never allocate — even for the 235B config."""
    cfg = configs.get_config("qwen3-moe-235b-a22b")
    tree = S.param_specs_for(cfg)
    leaves = jax.tree_util.tree_leaves(tree)
    assert all(isinstance(l, jax.ShapeDtypeStruct) for l in leaves)
    import math
    n = sum(math.prod(l.shape) for l in leaves)
    assert n > 2e11        # ~235B params represented, zero bytes allocated
