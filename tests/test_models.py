"""Per-architecture smoke tests (assignment requirement): reduced config of
the same family, one forward/train step on CPU, output shapes + no NaNs —
plus decode-path and family-specific math checks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import get_family, rwkv6
from repro.optim import adamw
from repro.train.step import make_train_step

B, S = 2, 16


def _batch(cfg, key):
    if cfg.input_mode == "embeds":
        return {"embeds": jax.random.normal(key, (B, S, cfg.d_model)),
                "labels": jax.random.randint(key, (B, S), 0, cfg.vocab)}
    if cfg.input_mode == "encdec":
        return {"frames": jax.random.normal(key, (B, S, cfg.d_model)),
                "tokens": jax.random.randint(key, (B, cfg.dec_len), 0, cfg.vocab),
                "labels": jax.random.randint(key, (B, cfg.dec_len), 0, cfg.vocab)}
    return {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab),
            "labels": jax.random.randint(key, (B, S), 0, cfg.vocab)}


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = configs.get_smoke_config(arch)
    fam = get_family(cfg)
    key = jax.random.PRNGKey(0)
    params = fam.init(key, cfg)
    batch = _batch(cfg, key)
    logits = jax.jit(lambda p, b: fam.forward(p, b, cfg))(params, batch)
    want_s = cfg.dec_len if cfg.input_mode == "encdec" else S
    assert logits.shape == (B, want_s, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    # one full train step moves the loss
    step = make_train_step(cfg, adamw.AdamWConfig(lr=1e-2, warmup_steps=1,
                                                  total_steps=10))
    opt = adamw.init(params)
    p2, o2, m = jax.jit(step)(params, opt, batch)
    assert bool(jnp.isfinite(m["loss"]))
    l2 = fam.loss_fn(p2, batch, cfg)
    assert float(l2) < float(m["loss"])        # same batch: loss must drop


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_smoke_decode(arch):
    cfg = configs.get_smoke_config(arch)
    fam = get_family(cfg)
    key = jax.random.PRNGKey(0)
    params = fam.init(key, cfg)
    cache = fam.init_cache(cfg, B, 32)
    if cfg.input_mode == "encdec":
        enc_out = fam.encode(params, jax.random.normal(key, (B, S, cfg.d_model)), cfg)
        cache = fam.prefill_cross(params, enc_out, cache, cfg)
    tok = jnp.zeros((B,), jnp.int32)
    dec = jax.jit(lambda p, c, t: fam.decode_step(p, c, t, cfg))
    for _ in range(3):
        logits, cache = dec(params, cache, tok)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    assert logits.shape == (B, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert int(cache["pos"]) == 3


def test_dense_decode_matches_forward():
    """Teacher-forced decode == forward logits (cache correctness)."""
    cfg = configs.get_smoke_config("qwen2-7b")
    fam = get_family(cfg)
    key = jax.random.PRNGKey(1)
    params = fam.init(key, cfg)
    toks = jax.random.randint(key, (B, 8), 0, cfg.vocab)
    full = fam.forward(params, {"tokens": toks}, cfg)     # (B, 8, V)
    cache = fam.init_cache(cfg, B, 8)
    outs = []
    for t in range(8):
        logits, cache = fam.decode_step(params, cache, toks[:, t], cfg)
        outs.append(logits)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-2, atol=2e-2)


def test_rwkv_chunked_equals_sequential():
    key = jax.random.PRNGKey(0)
    Bh, Sh, H, hd = 2, 70, 3, 8
    ks = jax.random.split(key, 5)
    r, k, v = (jax.random.normal(ks[i], (Bh, Sh, H, hd)) for i in range(3))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (Bh, Sh, H, hd))) * 0.5 + 0.45
    u = jax.random.normal(ks[4], (H, hd)) * 0.1
    seq = rwkv6._wkv_sequential(r, k, v, w, u)
    for chunk in (16, 64):
        ch = rwkv6._wkv_chunked(r, k, v, w, u, chunk)
        np.testing.assert_allclose(np.asarray(ch), np.asarray(seq),
                                   rtol=2e-3, atol=2e-3)


def test_rwkv_decode_matches_forward():
    cfg = configs.get_smoke_config("rwkv6-1.6b")
    fam = get_family(cfg)
    key = jax.random.PRNGKey(2)
    params = fam.init(key, cfg)
    toks = jax.random.randint(key, (B, 6), 0, cfg.vocab)
    full = fam.forward(params, {"tokens": toks}, cfg)
    cache = fam.init_cache(cfg, B, 6)
    outs = []
    for t in range(6):
        logits, cache = fam.decode_step(params, cache, toks[:, t], cfg)
        outs.append(logits)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=3e-2, atol=3e-2)


def test_rglru_decode_matches_forward():
    cfg = configs.get_smoke_config("recurrentgemma-9b")
    fam = get_family(cfg)
    key = jax.random.PRNGKey(3)
    params = fam.init(key, cfg)
    toks = jax.random.randint(key, (B, 6), 0, cfg.vocab)
    full = fam.forward(params, {"tokens": toks}, cfg)
    cache = fam.init_cache(cfg, B, 32)
    outs = []
    for t in range(6):
        logits, cache = fam.decode_step(params, cache, toks[:, t], cfg)
        outs.append(logits)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=3e-2, atol=3e-2)


def test_windowed_attention_matches_causal_within_window():
    from repro.models import layers as L
    cfg = configs.get_smoke_config("recurrentgemma-9b").replace(window=8)
    key = jax.random.PRNGKey(4)
    p = L.attn_init(key, cfg)
    x = jax.random.normal(key, (2, 24, cfg.d_model), jnp.float32)
    got = L.windowed_attention(p, x, cfg)
    # manual windowed reference: full attention with band mask
    q, k, v = L.qkv_project(p, x, cfg, jnp.arange(24)[None])
    qpos = jnp.arange(24)
    rel = qpos[:, None] - qpos[None, :]
    mask = ((rel >= 0) & (rel < cfg.window))[None, None]
    want = L._sdpa(q, k, v, mask, cfg) @ p["wo"]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-2, atol=2e-2)


def test_moe_capacity_drops_gracefully():
    from repro.models import moe
    cfg = configs.get_smoke_config("qwen3-moe-30b-a3b").replace(
        capacity_factor=0.5)
    key = jax.random.PRNGKey(5)
    p = moe.moe_init(key, cfg)
    x = jax.random.normal(key, (2, 16, cfg.d_model), jnp.float32)
    out = moe.apply_moe(p, x, cfg)
    assert out.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(out)))
