"""Infra tests: HLO collective parser, roofline math, token pipeline
determinism, serving engine, semantic planner, doc consistency."""
import re
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.tokens import TokenPipeline
from repro.utils import hlo as H, roofline


SAMPLE_HLO = """\
HloModule jit_step, is_scheduled=true

%cond (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant(12)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %x = f32[8,8] get-tuple-element(%p), index=1
  %ar = f32[8,8]{1,0} all-reduce(%x), channel_id=1, replica_groups=[4,2]<=[8], to_apply=%add
  %i = s32[] get-tuple-element(%p), index=0
  ROOT %t = (s32[], f32[8,8]) tuple(%i, %ar)
}

ENTRY %main (a: f32[8,8], b: f32[16,4]) -> f32[8,8] {
  %a = f32[8,8] parameter(0)
  %b = f32[16,4] parameter(1)
  %ag = f32[64,4]{1,0} all-gather(%b), channel_id=2, replica_groups=[2,4]<=[8], dimensions={0}
  %t0 = (s32[], f32[8,8]) tuple(%zero, %a)
  %w = (s32[], f32[8,8]) while(%t0), condition=%cond, body=%body
  ROOT %out = f32[8,8] get-tuple-element(%w), index=1
}
"""


def test_hlo_collective_parser_with_loop_multiplier():
    got = H.collective_bytes(SAMPLE_HLO)
    # all-reduce: 8*8*4 = 256B result, g=2, ring wire = 2*256*(1/2) = 256B,
    # inside a 12-trip loop -> 3072
    assert got["per_op"]["all-reduce"] == 256 * 12
    assert got["counts"]["all-reduce"] == 12
    # all-gather: result 64*4*4 = 1024B, g=4 -> 1024*3/4 = 768
    assert got["per_op"]["all-gather"] == 768
    assert H.while_trip_counts(SAMPLE_HLO) == [12]


def test_roofline_terms_and_dominance():
    rf = roofline.make(hlo_flops_per_dev=197e12 * 0.5,       # 0.5 s compute
                       hlo_bytes_per_dev=819e9 * 0.25,       # 0.25 s memory
                       collective_bytes_per_dev=50e9 * 1.0,  # 1.0 s collective
                       chips=256, model_flops=197e12 * 0.5 * 256 * 0.8)
    assert abs(rf.t_compute - 0.5) < 1e-9
    assert abs(rf.t_memory - 0.25) < 1e-9
    assert abs(rf.t_collective - 1.0) < 1e-9
    assert rf.dominant == "collective"
    assert abs(rf.useful_ratio - 0.8) < 1e-9
    assert abs(rf.step_time - 1.0) < 1e-9
    assert 0 < rf.mfu_bound < 1


def test_model_flops_kinds():
    from repro import configs
    cfg = configs.get_config("qwen3-moe-235b-a22b")
    info_t = {"kind": "train", "batch": 256, "seq": 4096}
    info_d = {"kind": "decode", "batch": 128, "seq": 32768}
    ft = roofline.model_flops_for(cfg, info_t)
    fd = roofline.model_flops_for(cfg, info_d)
    n_act = cfg.active_param_count()
    assert abs(ft - 6.0 * n_act * 256 * 4096) < 1e-3 * ft
    assert abs(fd - 2.0 * n_act * 128) < 1e-3 * fd


def test_token_pipeline_deterministic_and_restartable():
    p1 = TokenPipeline(vocab=100, batch=4, seq=8, seed=7)
    seq = [np.asarray(p1.next()["tokens"]) for _ in range(5)]
    # restart from a checkpointed cursor reproduces the stream
    p2 = TokenPipeline(vocab=100, batch=4, seq=8, seed=7)
    p2.load_state_dict({"seed": 7, "step": 3})
    np.testing.assert_array_equal(np.asarray(p2.next()["tokens"]), seq[3])
    np.testing.assert_array_equal(np.asarray(p2.next()["tokens"]), seq[4])
    # bigram structure: odd positions depend on even ones
    t = seq[0]
    assert ((t[:, 1::2] - t[:, 0::2]) % 100 <= 16).all()


def test_serving_engine_end_to_end():
    from repro import configs
    from repro.models import get_family
    from repro.serve.engine import Request, ServeEngine
    cfg = configs.get_smoke_config("qwen2-7b")
    fam = get_family(cfg)
    params = fam.init(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params, batch_slots=2, max_len=48)
    rng = np.random.default_rng(0)
    for rid in range(5):
        eng.submit(Request(rid=rid,
                           prompt=rng.integers(2, cfg.vocab, size=6),
                           max_new=4))
    done = eng.run()
    assert len(done) == 5
    assert all(1 <= len(r.out) <= 4 for r in done)


def test_design_doc_references_resolve():
    """Every ``DESIGN.md §N`` citation in src/ must name a real section.

    Docstrings across src/repro/ cite DESIGN.md sections (e.g. "DESIGN.md
    §3", "DESIGN.md §3/§7"); this keeps the document and the code from
    drifting apart.
    """
    root = Path(__file__).resolve().parents[1]
    design = (root / "DESIGN.md").read_text()
    headings = set(re.findall(r"^#+\s*§(\d+)", design, flags=re.M))
    assert headings, "DESIGN.md has no '§N' section headings"
    refs: dict[str, list[str]] = {}
    for p in sorted((root / "src").rglob("*.py")):
        for m in re.finditer(r"DESIGN\.md\s+((?:§\d+[/,]?\s?)+)",
                             p.read_text()):
            for sec in re.findall(r"§(\d+)", m.group(1)):
                refs.setdefault(sec, []).append(str(p.relative_to(root)))
    assert refs, "no DESIGN.md references found under src/"
    missing = {s: sorted(set(fs)) for s, fs in refs.items()
               if s not in headings}
    assert not missing, f"DESIGN.md sections cited but absent: {missing}"


def test_semantic_planner_plans_and_updates():
    from repro.core.config import ProberConfig
    from repro.serve.semantic import SemanticPlanner
    key = jax.random.PRNGKey(0)
    corpus = jax.random.normal(key, (2000, 32))
    cfg = ProberConfig(n_tables=1, n_funcs=6, ring_budget=512,
                       central_budget=512, chunk=128)
    planner = SemanticPlanner(corpus, cfg, key, max_calls=100, slot_budget=4)
    q = corpus[10]
    d2 = jnp.sort(jnp.sum((corpus - q) ** 2, axis=-1))
    tau_small = float(jnp.sqrt(d2[9]))
    plan = planner.plan(q, tau_small)
    assert plan.action == "execute"
    assert 1 <= plan.llm_calls <= 100
    assert plan.n_batches >= plan.llm_calls // 4
    # a huge tau must blow the budget -> refuse
    plan2 = planner.plan(q, 1e3)
    assert plan2.action == "refuse"
    # dynamic corpus update keeps working (paper §5)
    planner.update_corpus(jax.random.normal(jax.random.PRNGKey(1), (500, 32)))
    plan3 = planner.plan(q, tau_small)
    assert plan3.action == "execute"
