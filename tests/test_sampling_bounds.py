"""Property tests for the progressive-sampling Chernoff bounds (paper §4.5 +
Appendix 8.2): coverage, monotonicity, and the stopping semantics."""
import math

import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core import sampling


@settings(deadline=None)
@given(p_hat=st.floats(0.0, 1.0), w=st.floats(1.0, 1e6),
       delta=st.floats(1e-6, 0.1))
def test_bounds_order(p_hat, w, delta):
    a = math.log(1.0 / delta)
    lo = float(sampling.mu_lower(p_hat, w, a))
    hi = float(sampling.mu_upper(p_hat, w, a))
    assert 0.0 <= lo <= p_hat + 1e-6
    assert hi >= p_hat - 1e-6
    assert lo <= hi


@settings(deadline=None)
@given(p_hat=st.floats(0.0, 1.0), delta=st.floats(1e-6, 0.1))
def test_bounds_tighten_with_w(p_hat, delta):
    a = math.log(1.0 / delta)
    widths = []
    for w in (10.0, 100.0, 10_000.0):
        widths.append(float(sampling.mu_upper(p_hat, w, a))
                      - float(sampling.mu_lower(p_hat, w, a)))
    assert widths[0] >= widths[1] >= widths[2]


@settings(max_examples=25, deadline=None)
@given(p=st.floats(0.01, 0.5), seed=st.integers(0, 2**31 - 1))
def test_upper_bound_coverage(p, seed):
    """Pr(p <= mu_upper) >= 1 - delta, checked empirically (Appendix 8.2)."""
    rng = np.random.default_rng(seed)
    delta = 1e-3
    a = math.log(1.0 / delta)
    w = 400
    trials = 200
    failures = 0
    for _ in range(trials):
        p_hat = rng.binomial(w, p) / w
        if p > float(sampling.mu_upper(p_hat, w, a)):
            failures += 1
    # should fail ~delta of the time; allow generous slack for 200 trials
    assert failures <= max(3, int(0.05 * trials))


def test_stopping_conditions_consistency():
    a = math.log(1000.0)
    # tiny selectivity at a large sample -> both stop conditions fire
    assert bool(sampling.stop_probing(0.0, 1e5, a, eps=0.01))
    assert bool(sampling.stop_sampling(0.0, 1e5, a, eps=0.01))
    # moderate selectivity -> never a PTF even at huge samples
    assert not bool(sampling.stop_probing(0.3, 1e7, a, eps=0.01))
    # small sample: CI too wide to stop
    assert not bool(sampling.stop_sampling(0.3, 5, a, eps=0.01))


def test_ptf_implies_small_contribution():
    """If PTF fires, the ring's true selectivity is < eps w.h.p. — the
    justification for skipping farther rings (paper eq. (2))."""
    a = math.log(1000.0)
    eps = 0.01
    for w in (100, 1000, 10000):
        for wq in range(0, w + 1):
            p_hat = wq / w
            if bool(sampling.stop_probing(p_hat, float(w), a, eps)):
                assert float(sampling.mu_upper(p_hat, float(w), a)) < eps
