"""Capacity-padded dynamic updates (paper §5, DESIGN.md §10).

Covers: update-then-estimate equivalence vs a from-scratch build for the
in-capacity (recompile-free) and capacity-doubling paths, the
zero-new-compilations contract for in-capacity ingest, the capacity-padded
layout invariants, the jitted Alg. 9 neighbor-table step, the serve-layer
ingest path, and regressions for the serving-engine slot-position and
finished-request bugs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import compile_events

from repro.core import estimator as E, lsh, neighbors, updates
from repro.core.config import ProberConfig

CFG = ProberConfig(n_tables=2, n_funcs=6, ring_budget=512,
                   central_budget=512, chunk=128)
PQCFG = CFG.replace(use_pq=True, pq_m=4, pq_kc=16, pq_iters=4)


@pytest.fixture(scope="module")
def data():
    return jax.random.normal(jax.random.PRNGKey(0), (2048, 16))


def _stream(state, x_stream, cfg, chunk):
    for i in range(0, x_stream.shape[0], chunk):
        state = E.update(state, x_stream[i:i + chunk], cfg)
    return state


def _ests(st, cfg, qs, taus):
    return np.asarray(E.estimate_batch(st, qs, taus, cfg,
                                       jax.random.PRNGKey(7)))


@pytest.mark.parametrize("cfg", [CFG, PQCFG], ids=["exact", "pq"])
def test_incremental_equals_fresh_build_in_capacity(data, cfg):
    """K in-capacity updates ~ one build over the concatenated data."""
    key = jax.random.PRNGKey(0)
    n0, n = 1024, 1536
    st = E.build(data[:n0], cfg, key, capacity=4096)
    st = _stream(st, data[n0:n], cfg, chunk=128)
    assert int(st.n_valid) == n and st.capacity == 4096

    fresh = E.build(data[:n], cfg, key)
    qs = data[:6] + 0.01
    taus = jnp.linspace(3.0, 6.0, 6)
    got = _ests(st, cfg, qs, taus)
    want = _ests(fresh, cfg, qs, taus)
    truth = np.asarray([float(E.true_cardinality(data[:n], qs[i], taus[i]))
                        for i in range(6)])
    # same hash functions + exact Alg. 7 W renormalisation => the LSH layout
    # matches the fresh build; PQ centroids differ (incremental means), so
    # compare both paths against truth with matched tolerance
    ref = np.maximum(truth, 10.0)
    assert np.all(np.abs(got - truth) <= 1.0 * ref + 1e-6), (got, truth)
    assert np.all(np.abs(got - want) <= 0.75 * ref + 1e-6), (got, want)


def test_incremental_equals_fresh_build_through_doubling(data):
    """Growth path: stream past the initial capacity (several doublings)."""
    key = jax.random.PRNGKey(0)
    n0 = 512
    st = E.build(data[:n0], PQCFG, key, capacity=512)   # zero spare rows
    st = _stream(st, data[n0:], PQCFG, chunk=256)
    assert int(st.n_valid) == data.shape[0]
    assert st.capacity >= data.shape[0]

    fresh = E.build(data, PQCFG, key)
    qs = data[:5] + 0.01
    taus = jnp.linspace(3.0, 6.0, 5)
    got = _ests(st, PQCFG, qs, taus)
    truth = np.asarray([float(E.true_cardinality(data, qs[i], taus[i]))
                        for i in range(5)])
    want = _ests(fresh, PQCFG, qs, taus)
    ref = np.maximum(truth, 10.0)
    assert np.all(np.abs(got - truth) <= 1.0 * ref + 1e-6), (got, truth)
    assert np.all(np.abs(got - want) <= 0.75 * ref + 1e-6), (got, want)


def test_in_capacity_update_zero_new_compilations(data):
    """The recompile-free contract (DESIGN.md §10): once one in-capacity
    update of a given chunk shape has compiled, further updates (and the
    estimates between them) trigger ZERO new XLA compilations."""
    key = jax.random.PRNGKey(0)
    st = E.build(data[:1024], PQCFG, key, capacity=4096)
    q, tau = data[0] + 0.01, jnp.float32(4.0)
    E.estimate(st, q, tau, PQCFG, key)                    # warm estimate
    st = E.update(st, data[1024:1152], PQCFG)             # warm ingest @128
    E.estimate(st, q, tau, PQCFG, key)

    with compile_events() as ev:
        st = E.update(st, data[1152:1280], PQCFG)
        st = E.update(st, data[1280:1408], PQCFG)
        est = float(E.estimate(st, q, tau, PQCFG, key))
    assert ev == [], f"in-capacity update recompiled: {ev}"
    assert int(st.n_valid) == 1408
    assert 0.0 <= est <= 1408


def test_padded_build_matches_plain_build_estimates(data):
    """Capacity padding must not change results: a padded build estimates
    exactly like the same build without spare rows (same keys)."""
    key = jax.random.PRNGKey(1)
    qs = data[:4] + 0.01
    taus = jnp.linspace(3.0, 6.0, 4)
    plain = E.build(data[:1000], CFG, key)
    padded = E.build(data[:1000], CFG, key, capacity=3000)
    np.testing.assert_array_equal(_ests(plain, CFG, qs, taus),
                                  _ests(padded, CFG, qs, taus))


def test_padded_layout_invariants(data):
    """Sentinel bucket: live buckets partition exactly the live rows;
    padding rows sit past every live CSR entry."""
    idx = E.build(data[:1000], CFG, jax.random.PRNGKey(2),
                  capacity=2048).index
    assert int(idx.n_valid) == 1000
    for t in range(idx.n_tables):
        nb = int(idx.n_buckets[t])
        sizes = np.asarray(idx.bucket_sizes[t])
        starts = np.asarray(idx.bucket_starts[t])
        order = np.asarray(idx.order[t])
        assert sizes[:nb].sum() == 1000
        assert starts[0] == 0
        np.testing.assert_array_equal(starts[1:nb],
                                      np.cumsum(sizes[:nb])[:-1])
        # live CSR rows reference live points only; dead ids fill the tail
        assert sorted(order[:1000].tolist()) == list(range(1000))
        assert sorted(order[1000:].tolist()) == list(range(1000, 2048))
        # padding point codes are sentinel
        assert (np.asarray(idx.codes[t][1000:]) == lsh.CODE_SENTINEL).all()


def test_neighbor_update_jitted_fixed_shape():
    """Alg. 9 as a fixed-shape jitted step over capacity-padded codes."""
    key = jax.random.PRNGKey(3)
    old = np.unique(np.asarray(
        jax.random.randint(key, (30, 5), 0, 4)), axis=0)
    new = np.asarray(jax.random.randint(jax.random.PRNGKey(4), (6, 5), 0, 4))
    n_old, n_all, cap = len(old), len(old) + len(new), 64
    codes_pad = np.full((cap, 5), lsh.CODE_SENTINEL, np.int32)
    codes_pad[:n_old] = old
    codes_pad[n_old:n_all] = new
    table = neighbors.build(jnp.asarray(codes_pad[:n_old]),
                            jnp.int32(n_old), max_dist=4)
    table = neighbors.grow(table, cap)
    step = jax.jit(neighbors.update)
    updated = step(table, jnp.asarray(codes_pad), jnp.int32(n_old),
                   jnp.int32(n_all))
    fresh = neighbors.build(jnp.asarray(codes_pad[:n_all]),
                            jnp.int32(n_all), max_dist=4)
    np.testing.assert_array_equal(
        np.asarray(updated.dists)[:n_all, :n_all],
        np.asarray(fresh.dists))
    # a second jitted call with in-capacity shapes adds no compilation
    with compile_events() as ev:
        step(updated, jnp.asarray(codes_pad), jnp.int32(n_all),
             jnp.int32(n_all))
    assert ev == []


def test_coalescer_ingest_interleaves_with_estimates(data):
    """Serve-layer ingest: estimates after ingest() see the new points."""
    from repro.serve.engine import CardinalityCoalescer
    cfg = CFG.replace(ingest_chunk=128)
    key = jax.random.PRNGKey(5)
    st = E.build(data[:1024], cfg, key, capacity=4096)
    co = CardinalityCoalescer(st, cfg, key, max_batch=8)
    # a point far from the initial corpus: cardinality ~0 before ingest
    far = data[0] + 50.0
    r0 = co.submit(np.asarray(far), 3.0)
    co.flush()
    assert r0.est is not None and r0.est < 1.0
    # ingest a cluster AT that location (> one chunk, with a partial tail)
    cluster = far[None, :] + 0.1 * np.asarray(
        jax.random.normal(jax.random.PRNGKey(6), (300, 16)))
    left = co.ingest(cluster)
    assert left < 128                       # full chunks applied eagerly
    r1 = co.submit(np.asarray(far), 3.0)
    co.flush()                              # drains the partial chunk first
    assert int(co.state.n_valid) == 1024 + 300
    assert r1.est > 100.0, r1.est           # the cluster is now visible


def _smoke_engine(batch_slots=2, max_len=48):
    from repro import configs
    from repro.models import get_family
    from repro.serve.engine import ServeEngine
    cfg = configs.get_smoke_config("qwen2-7b")
    fam = get_family(cfg)
    params = fam.init(jax.random.PRNGKey(0), cfg)
    return ServeEngine(cfg, params, batch_slots=batch_slots, max_len=max_len)


def test_engine_per_slot_positions():
    """Regression: a slot admitted after a longer request must keep its own
    decode position, not inherit the max across slots."""
    from repro.serve.engine import Request
    eng = _smoke_engine()
    rng = np.random.default_rng(1)
    eng.submit(Request(rid=0, prompt=rng.integers(2, 50, size=20), max_new=6))
    eng.submit(Request(rid=1, prompt=rng.integers(2, 50, size=4), max_new=6))
    eng.step()
    pos = np.asarray(eng.cache["pos"])
    # after one decode step: prompt_len + 1 each, independently
    assert pos[0] == 21 and pos[1] == 5, pos
    done = eng.run()
    assert {r.rid for r in done} == {0, 1}
    assert all(len(r.out) == 6 for r in done)


def test_engine_short_slot_not_retired_by_long_neighbor():
    """Regression: the max_len retirement check must be per-slot — the long
    request hitting the cache ceiling used to retire every live slot."""
    from repro.serve.engine import Request
    eng = _smoke_engine(max_len=24)
    rng = np.random.default_rng(2)
    eng.submit(Request(rid=0, prompt=rng.integers(2, 50, size=20),
                       max_new=16))
    eng.submit(Request(rid=1, prompt=rng.integers(2, 50, size=3),
                       max_new=16))
    done = eng.run()
    by_rid = {r.rid: r for r in done}
    assert set(by_rid) == {0, 1}
    # slot 0 retires at the cache ceiling (24 - 21 = 3 decode steps); slot 1
    # has plenty of headroom and must reach its own max_new budget
    assert len(by_rid[0].out) < 16
    assert len(by_rid[1].out) == 16


def test_engine_run_returns_midrun_and_preadmitted_requests():
    """Regression: run() snapshotted the queue at entry, losing requests
    already admitted to slots and requests submitted while running."""
    from repro.serve.engine import Request
    eng = _smoke_engine()
    rng = np.random.default_rng(3)
    eng.submit(Request(rid=0, prompt=rng.integers(2, 50, size=4), max_new=3))
    eng.step()                    # rid 0 admitted to a slot, queue now empty
    eng.submit(Request(rid=1, prompt=rng.integers(2, 50, size=4), max_new=3))
    done = eng.run()
    assert {r.rid for r in done} == {0, 1}
    assert all(r.done for r in done)
    # nothing is returned twice
    assert eng.run(max_steps=4) == []
