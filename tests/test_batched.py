"""Batched estimation path (DESIGN.md §9).

The contract under test: ``estimate_batch`` over Q queries is bit-for-bit
identical to Q sequential ``estimate`` calls with the same per-query PRNG
keys — for the exact path, the PQ path and the full-ADC serving trade —
and the batched ADC kernel / serve-layer coalescer agree with their
per-query counterparts.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import estimator as E, pq as pqmod, prober
from repro.core.config import ProberConfig
from repro.kernels import adc as adc_mod

CFG = ProberConfig(n_tables=2, n_funcs=6, ring_budget=512,
                   central_budget=512, chunk=128)


@pytest.fixture(scope="module")
def data():
    return jax.random.normal(jax.random.PRNGKey(0), (2000, 32))


@pytest.fixture(scope="module")
def state(data):
    return E.build(data, CFG, jax.random.PRNGKey(0))


def _qs_taus(x, q=6):
    return x[:q] + 0.01, jnp.linspace(4.0, 9.0, q)


def _assert_batch_matches_sequential(st, cfg, qs, taus):
    key = jax.random.PRNGKey(7)
    keys = jax.random.split(key, qs.shape[0])
    batch = E.estimate_batch(st, qs, taus, cfg, key)
    seq = jnp.stack([E.estimate(st, qs[i], taus[i], cfg, keys[i])
                     for i in range(qs.shape[0])])
    np.testing.assert_array_equal(np.asarray(batch), np.asarray(seq))
    assert np.asarray(batch).std() > 0   # the workload is non-degenerate


def test_estimate_batch_bitwise_exact(data, state):
    qs, taus = _qs_taus(data)
    _assert_batch_matches_sequential(state, CFG, qs, taus)


def test_estimate_batch_bitwise_pq(data):
    cfg = CFG.replace(use_pq=True, pq_m=8, pq_kc=16, pq_iters=4)
    st = E.build(data, cfg, jax.random.PRNGKey(0))
    qs, taus = _qs_taus(data)
    _assert_batch_matches_sequential(st, cfg, qs, taus)


def test_estimate_batch_bitwise_full_adc(data):
    """The serving trade (DESIGN.md §9): ADC for the central bucket too."""
    cfg = CFG.replace(use_pq=True, pq_m=8, pq_kc=16, pq_iters=4,
                      pq_exact_rings=0, pq_exact_central=False, chunk=256)
    st = E.build(data, cfg, jax.random.PRNGKey(0))
    qs, taus = _qs_taus(data)
    _assert_batch_matches_sequential(st, cfg, qs, taus)


def test_adc_batch_kernel_matches_per_query():
    key = jax.random.PRNGKey(1)
    n, m, kc, q = 777, 8, 32, 5       # n % bn != 0 exercises the padding
    kc_, kl = jax.random.split(key)
    codes = jax.random.randint(kc_, (n, m), 0, kc).astype(jnp.uint8)
    luts = jax.random.uniform(kl, (q, m, kc), dtype=jnp.float32)
    got = adc_mod.adc_batch(codes, luts, bn=256, interpret=True)
    assert got.shape == (q, n)
    single = jnp.stack([adc_mod.adc(codes, luts[i], bn=256, interpret=True)
                        for i in range(q)])
    np.testing.assert_allclose(np.asarray(got), np.asarray(single),
                               rtol=1e-6, atol=1e-5)
    ref = jnp.stack([pqmod.adc_distance(luts[i], codes) for i in range(q)])
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-5, atol=1e-4)


def test_prp_eval_bijective_on_dynamic_domains():
    rks = jax.random.bits(jax.random.PRNGKey(3), (6,), jnp.uint32)
    for nbits in (0, 1, 3, 7, 11):
        p = 1 << nbits
        out = np.asarray(prober._prp_eval(
            jnp.arange(p, dtype=jnp.uint32), rks, jnp.int32(p - 1),
            jnp.int32(nbits)))
        assert sorted(out.tolist()) == list(range(p)), nbits


def test_coalescer_matches_direct_estimate_batch(data, state):
    from repro.serve.engine import CardinalityCoalescer
    qs, taus = _qs_taus(data, 5)
    key = jax.random.PRNGKey(11)
    co = CardinalityCoalescer(state, CFG, key, max_batch=8)
    reqs = [co.submit(np.asarray(qs[i]), float(taus[i])) for i in range(5)]
    out = co.flush()
    # flush 0 pads 5 -> 8 and derives its key as fold_in(key, 0)
    pad_qs = jnp.zeros((8, qs.shape[1]), jnp.float32).at[:5].set(qs)
    pad_taus = jnp.zeros((8,), jnp.float32).at[:5].set(taus)
    want = E.estimate_batch(state, pad_qs, pad_taus, CFG,
                            jax.random.fold_in(key, 0))[:5]
    assert len(out) == 5
    for i, r in enumerate(reqs):
        assert out[r.rid] == r.est == float(want[i])
    assert not co.pending


def test_coalescer_auto_flush_at_max_batch(data, state):
    from repro.serve.engine import CardinalityCoalescer
    co = CardinalityCoalescer(state, CFG, jax.random.PRNGKey(0), max_batch=4)
    reqs = [co.submit(np.asarray(data[i]), 5.0) for i in range(4)]
    assert all(r.est is not None for r in reqs)   # submit #4 flushed
    assert not co.pending


def test_planner_plan_batch_consistent(data):
    from repro.serve.semantic import SemanticPlanner
    planner = SemanticPlanner(data, CFG, jax.random.PRNGKey(0),
                              max_calls=500, slot_budget=4)
    qs, taus = _qs_taus(data, 4)
    plans = planner.plan_batch(np.asarray(qs), np.asarray(taus))
    assert len(plans) == 4
    for p in plans:
        assert p.action in ("execute", "refuse")
        if p.action == "execute" and p.llm_calls:
            assert p.n_batches == -(-p.llm_calls // p.batch_slots)
