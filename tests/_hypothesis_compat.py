"""Shared fallback for the optional ``hypothesis`` dependency.

The baked image does not ship hypothesis; property tests import
``given``/``settings``/``st`` from here so that ONLY the property tests
skip while plain tests in the same module still run.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
except ImportError:
    class _SkipStrategies:
        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _SkipStrategies()

    def given(*a, **k):
        return lambda f: pytest.mark.skip(
            reason="hypothesis not installed")(f)

    def settings(*a, **k):
        return lambda f: f
