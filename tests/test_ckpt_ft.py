"""Checkpointing + fault tolerance: atomic publish, restore-latest-valid,
bit-exact restart continuation, gradient compression, straggler policy."""
import json
import shutil
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.checkpoint import CheckpointManager
from repro.data.tokens import TokenPipeline
from repro.ft.failures import FaultTolerantLoop, HeartbeatMonitor, WorkerFailure
from repro.ft.straggler import StragglerDetector
from repro.optim import adamw, compression


def _tiny_state(key):
    return {"params": {"w": jax.random.normal(key, (4, 4)),
                       "b": jnp.zeros((4,))},
            "count": jnp.zeros((), jnp.int32)}


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    st = _tiny_state(jax.random.PRNGKey(0))
    mgr.save(5, st, extra={"pipeline": {"seed": 1, "step": 5}})
    got = mgr.restore(st)
    assert got is not None
    restored, extra, step = got
    assert step == 5 and extra["pipeline"]["step"] == 5
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(st["params"]["w"]))


def test_retention_and_latest(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    st = _tiny_state(jax.random.PRNGKey(0))
    for s in (1, 2, 3, 4):
        mgr.save(s, st)
    assert mgr.latest_step() == 4
    kept = sorted(p.name for p in Path(tmp_path).glob("step_*"))
    assert kept == ["step_00000003", "step_00000004"]


def test_torn_save_falls_back(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=3)
    st = _tiny_state(jax.random.PRNGKey(0))
    mgr.save(1, st)
    mgr.save(2, st)
    # corrupt the newest: delete its manifest (simulates a torn write)
    (Path(tmp_path) / "step_00000002" / "manifest.json").unlink()
    assert mgr.latest_step() == 1
    got = mgr.restore(st)
    assert got[2] == 1


def test_async_save(tmp_path):
    mgr = CheckpointManager(tmp_path)
    st = _tiny_state(jax.random.PRNGKey(1))
    mgr.save_async(7, st)
    mgr.wait()
    assert mgr.latest_step() == 7


def _make_loop(tmp_path, save_every=5):
    opt_cfg = adamw.AdamWConfig(lr=1e-2, warmup_steps=1, total_steps=100)

    @jax.jit
    def train(params, opt, batch):
        def loss_fn(p):
            pred = batch["x"] @ p["w"] + p["b"]
            return jnp.mean((pred - batch["y"]) ** 2)
        loss, grads = jax.value_and_grad(loss_fn)(params)
        p2, o2, m = adamw.update(grads, opt, params, opt_cfg)
        m["loss"] = loss
        return p2, o2, m

    class XYPipeline(TokenPipeline):
        def _batch_at(self, step):
            key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
            x = jax.random.normal(key, (8, 4))
            w_true = jnp.eye(4)
            return {"x": x, "y": x @ w_true}

    params = {"w": jax.random.normal(jax.random.PRNGKey(0), (4, 4)),
              "b": jnp.zeros((4,))}
    state = {"params": params, "opt": adamw.init(params)}

    def step_fn(state, batch):
        p, o, m = train(state["params"], state["opt"], batch)
        return {"params": p, "opt": o}, {"loss": m["loss"]}

    pipeline = XYPipeline(vocab=1, batch=8, seq=1, seed=0)
    mgr = CheckpointManager(tmp_path, keep=3)
    return FaultTolerantLoop(step_fn, mgr, pipeline, save_every=save_every), state


def test_ft_loop_identical_with_and_without_failures(tmp_path):
    """Injected failures + restore must reproduce the exact no-failure run."""
    loop_a, state_a = _make_loop(tmp_path / "a")
    final_a, log_a = loop_a.run(state_a, 20)

    fail_at = {7, 13}
    fired = set()

    def inject(step):
        if step in fail_at and step not in fired:
            fired.add(step)
            return True
        return False

    loop_b, state_b = _make_loop(tmp_path / "b")
    final_b, log_b = loop_b.run(state_b, 20, inject=inject)
    assert loop_b.restarts == 2
    np.testing.assert_allclose(np.asarray(final_a["params"]["w"]),
                               np.asarray(final_b["params"]["w"]),
                               rtol=1e-6)
    assert abs(log_a[-1]["loss"] - log_b[-1]["loss"]) < 1e-6


def test_heartbeat_monitor():
    hb = HeartbeatMonitor(4, timeout=10.0)
    for r in range(4):
        hb.beat(r, now=100.0)
    hb.beat(2, now=200.0)
    assert sorted(hb.dead_ranks(now=205.0)) == [0, 1, 3]


def test_straggler_detector():
    det = StragglerDetector(threshold=1.5)
    for step in range(6):
        for rank in range(8):
            det.record(rank, 1.0 if rank != 3 else 2.5)
    assert det.stragglers() == [3]
    assert det.mitigation(3) in ("rebalance", "evict")


def test_compression_error_feedback_unbiased():
    """Over many steps the EF residual keeps compressed SGD unbiased: the
    cumulative applied update approaches the cumulative true gradient."""
    key = jax.random.PRNGKey(0)
    grads = {"w": jax.random.normal(key, (64, 64))}
    state = compression.init_state(grads)
    applied = jnp.zeros((64, 64))
    total = jnp.zeros((64, 64))
    for i in range(30):
        g = {"w": jax.random.normal(jax.random.fold_in(key, i), (64, 64))}
        qs, ss, state = compression.compress_tree(g, state)
        out = compression.decompress_tree(qs, ss)
        applied = applied + out["w"]
        total = total + g["w"]
    # residual bounds the gap: |sum(applied) - sum(true)| = |residual|
    gap = jnp.abs(applied - total)
    np.testing.assert_allclose(np.asarray(gap),
                               np.asarray(jnp.abs(state.residual["w"])),
                               rtol=1e-3, atol=1e-3)
    assert float(jnp.max(gap)) < 0.1      # one int8 quantum


def test_elastic_plan():
    from repro.ft.elastic import plan_remesh
    plan = plan_remesh(n_alive=250, model_parallel=16)
    assert plan.model == 16 and plan.data == 15 and plan.n_devices == 240
    with pytest.raises(AssertionError):
        plan_remesh(n_alive=8, model_parallel=16)
