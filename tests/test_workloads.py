"""Workload generator smoke tests (DESIGN.md §12) — seeded, tiny N.

Tier-1 guards for benchmarks/workloads.py: determinism (same seed, same
stream), schema, zipfian head concentration, drift non-stationarity, tau
band correlation, and the mixed stream's ingest events — plus a micro
end-to-end run of the bench harness's serve loop so the cache
partition/merge step can't regress silently outside CI's bench smoke.
"""
import numpy as np
import pytest

from benchmarks import workloads


@pytest.fixture(scope="module")
def ds():
    from repro.data import vectors
    return vectors.load("sift", n_queries=4, scale=0.02)   # 800 x 128


def test_streams_deterministic(ds):
    a = workloads.generate(ds, "zipf", n_events=64, pool=16, seed=7)
    b = workloads.generate(ds, "zipf", n_events=64, pool=16, seed=7)
    assert a.events == b.events
    np.testing.assert_array_equal(a.taus, b.taus)
    c = workloads.generate(ds, "zipf", n_events=64, pool=16, seed=8)
    assert c.events != a.events


def test_schema_and_truth(ds):
    wl = workloads.generate(ds, "zipf", n_events=64, pool=16, seed=0)
    assert wl.n_queries == 64
    for kind, payload in wl.events:
        assert kind == "q"
        q, tau, truth = wl.request(payload)
        assert q.shape == (ds.x.shape[1],) and tau > 0 and truth >= 0
    # truth matches the dataset's exact grid cardinalities
    d2 = np.sum((np.asarray(ds.x) - wl.qs[0]) ** 2, axis=-1)
    assert np.sum(d2 <= wl.taus[0] ** 2) == wl.truth[0]


def test_zipf_head_concentration(ds):
    wl = workloads.generate(ds, "zipf", n_events=512, pool=32, skew=0.99,
                            seed=0)
    counts = np.bincount([p for _, p in wl.events], minlength=32)
    # the head must dominate a uniform draw (512/32 = 16 per key)
    assert counts.max() > 4 * 512 / 32
    assert (counts > 0).sum() < 32                 # and the tail is thin


def test_drift_changes_popular_set(ds):
    wl = workloads.generate(ds, "drift", n_events=512, pool=48, seed=0,
                            phase_len=128)
    early = {p for _, p in wl.events[:128]}
    late = {p for _, p in wl.events[-128:]}
    assert late - early, "popularity window never moved"


def test_tau_corr_bands_per_query(ds):
    wl = workloads.generate(ds, "tau-corr", n_events=256, pool=8, seed=0,
                            tau_band=2)
    by_query: dict = {}
    for _, p in wl.events:
        by_query.setdefault(wl.qs[p].tobytes(), set()).add(float(wl.taus[p]))
    assert by_query, "no events"
    assert all(1 <= len(ts) <= 2 for ts in by_query.values()), \
        "a client wandered outside its tau band"


def test_mixed_stream_has_ingests(ds):
    wl = workloads.generate(ds, "mixed", n_events=128, pool=16, seed=0,
                            ingest_every=32, ingest_n=8)
    kinds = [k for k, _ in wl.events]
    assert kinds.count("ingest") == 3              # t = 32, 64, 96
    for kind, payload in wl.events:
        if kind == "ingest":
            assert payload.shape == (8, ds.x.shape[1])
            assert payload.dtype == np.float32


def test_harness_micro_end_to_end(ds):
    """The bench harness's serve loop over a tiny mixed stream: hits
    appear, stale refreshes appear after ingest, nothing crashes, and the
    cached side's estimates for exact repeats match the fresh-probe values
    recorded at insert time."""
    import jax

    from benchmarks import bench_latency
    from repro.core import estimator as E, updates as U
    from repro.core.config import ProberConfig
    from repro.serve.engine import CardinalityCoalescer

    cfg = ProberConfig(n_tables=1, n_funcs=8, ring_budget=256,
                       central_budget=256, chunk=128, max_visit=512,
                       ingest_chunk=64)
    wl = workloads.generate(ds, "mixed", n_events=48, pool=8, seed=0,
                            ingest_every=16, ingest_n=8)
    n = ds.x.shape[0]
    n_ingest = sum(e[1].shape[0] for e in wl.events if e[0] == "ingest")
    state = E.build(ds.x, cfg, jax.random.PRNGKey(0), track_epochs=True,
                    capacity=U.next_capacity(n, n + n_ingest))
    co = CardinalityCoalescer(state, cfg, jax.random.PRNGKey(0),
                              max_batch=8, cache_size=32)
    qps, served = bench_latency._serve_workload(wl, co, batch=8)
    assert qps > 0 and len(served) == wl.n_queries
    assert co.cache_stats["hits"] > 0
    assert co.cache_stats["lookups"] == wl.n_queries
    first_serve: dict = {}
    for pi, req in served:
        if req.provenance == "hit":
            assert req.est == first_serve[pi]      # replays, bit-identical
        else:
            first_serve[pi] = req.est
