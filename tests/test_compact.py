"""Skew-resilient compacting probe scheduler (DESIGN.md §11).

The contract under test: the compacted flat-lane scheduler
(``cfg.lane_block > 0``) is BIT-IDENTICAL to the monolithic vmapped
``while_loop`` (``lane_block=0``) for every (lane_block, lane_tile)
combination, every qualification datapath, and skewed workloads where
lanes finish at very different slab counts — plus the serving contract
that compaction adds no per-flush recompiles in the coalescer.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import compile_events

from repro.core import estimator as E
from repro.core.config import ProberConfig

CFG = ProberConfig(n_tables=2, n_funcs=6, ring_budget=512,
                   central_budget=512, chunk=128)


@pytest.fixture(scope="module")
def data():
    return jax.random.normal(jax.random.PRNGKey(0), (2000, 32))


def _skewed_qs_taus(x, q=8):
    """A (tau, query) mix with strong lane skew: most lanes stop after a
    couple of slabs (tiny tau -> PTF), a few run long (large tau)."""
    qs = x[:q] + 0.01
    taus = jnp.where(jnp.arange(q) % 4 == 0, 9.5, 2.0)
    return qs, taus


def _compare_schedulers(st, cfg, qs, taus):
    # tile sizes stay BELOW the lane count (Q=8 x L=2 = 16 lanes) so every
    # combination actually routes through the compacting scheduler
    # (batches of <= lane_tile lanes fall back to the monolithic loop)
    key = jax.random.PRNGKey(7)
    mono = E.estimate_batch(st, qs, taus, cfg.replace(lane_block=0), key)
    for block, tile in [(1, 4), (4, 8), (7, 3), (2, 1), (4, 15)]:
        got = E.estimate_batch(
            st, qs, taus, cfg.replace(lane_block=block, lane_tile=tile), key)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(mono),
                                      err_msg=f"block={block} tile={tile}")
    # Q*L <= lane_tile routes to the monolithic loop (trivially equal, but
    # exercises the routing itself)
    got = E.estimate_batch(st, qs, taus,
                           cfg.replace(lane_block=4, lane_tile=64), key)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(mono))
    assert np.asarray(mono).std() > 0     # the workload is non-degenerate
    return mono


def test_compact_bitwise_exact_skewed(data):
    st = E.build(data, CFG, jax.random.PRNGKey(0))
    qs, taus = _skewed_qs_taus(data)
    _compare_schedulers(st, CFG, qs, taus)


def test_compact_bitwise_pq(data):
    cfg = CFG.replace(use_pq=True, pq_m=8, pq_kc=16, pq_iters=4)
    st = E.build(data, cfg, jax.random.PRNGKey(0))
    qs, taus = _skewed_qs_taus(data)
    _compare_schedulers(st, cfg, qs, taus)


def test_compact_bitwise_full_adc_serving(data):
    """The serving trade (DESIGN.md §9) + quantized LUT (DESIGN.md §11)."""
    cfg = CFG.replace(use_pq=True, pq_m=8, pq_kc=16, pq_iters=4,
                      pq_exact_rings=0, pq_exact_central=False, chunk=256,
                      pq_int8_lut=True)
    st = E.build(data, cfg, jax.random.PRNGKey(0))
    qs, taus = _skewed_qs_taus(data)
    _compare_schedulers(st, cfg, qs, taus)


def test_compact_matches_sequential_single_query(data):
    """Transitivity check straight to the per-query path: the compacted
    batch equals Q sequential ``estimate`` calls (which always run the
    monolithic loop) with the same per-query keys. ``lane_tile=4`` keeps
    the 5x2-lane batch on the compacting path."""
    cfg = CFG.replace(lane_tile=4)
    st = E.build(data, cfg, jax.random.PRNGKey(0))
    qs, taus = _skewed_qs_taus(data, 5)
    key = jax.random.PRNGKey(11)
    keys = jax.random.split(key, 5)
    batch = E.estimate_batch(st, qs, taus, cfg, key)
    seq = jnp.stack([E.estimate(st, qs[i], taus[i], cfg, keys[i])
                     for i in range(5)])
    np.testing.assert_array_equal(np.asarray(batch), np.asarray(seq))


def test_visit_budget_no_overshoot(data):
    """The in-progress ring's sample count folds into the budget check each
    slab (bugfix): with a budget smaller than one ring's worth of samples,
    ``nvisited`` must stop within one chunk of the budget instead of
    overshooting by a whole ring."""
    from repro.core import lsh, prober

    cfg = CFG.replace(max_visit=256, chunk=128, ring_budget=512,
                      s1=1.0, eps=1e-6)   # tight eps -> rings sample fully
    st = E.build(data, cfg, jax.random.PRNGKey(0))
    views = prober.table_views(st.index)
    view = jax.tree_util.tree_map(lambda a: a[0], views)
    qcode = lsh.hash_point(st.index.params, data[0] + 0.01,
                           st.index.n_tables)[0]
    qualfn = prober.make_exact_qualfn(st.x, data[0] + 0.01, jnp.float32(81.0))
    est, nvisited = prober.estimate_one_table(view, qcode, qualfn, cfg,
                                              jax.random.PRNGKey(3))
    nvisited = int(nvisited)
    assert nvisited >= cfg.max_visit          # the budget actually bound
    assert nvisited <= cfg.max_visit + cfg.chunk, nvisited
    assert float(est) > 0


def test_coalescer_compaction_no_per_flush_recompiles(data):
    """Serving contract (DESIGN.md §11): the compacting scheduler compiles
    once per flush shape — repeated coalescer flushes at the same padded
    batch size trigger ZERO new XLA compilations. ``lane_tile=4`` keeps the
    padded 4x2-lane flush on the compacting path."""
    from repro.serve.engine import CardinalityCoalescer

    cfg = CFG.replace(lane_tile=4)
    st = E.build(data, cfg, jax.random.PRNGKey(0))
    assert cfg.lane_block > 0      # compaction is on in the default config
    co = CardinalityCoalescer(st, cfg, jax.random.PRNGKey(0), max_batch=8)
    for i in range(3):             # warm: compiles the padded-4 flush shape
        co.submit(np.asarray(data[i]), 5.0)
    out0 = co.flush()
    assert len(out0) == 3
    with compile_events() as ev:
        for i in range(3):
            co.submit(np.asarray(data[3 + i]), 5.0 + i)
        out1 = co.flush()
    assert len(out1) == 3
    assert not ev, f"flush recompiled: {ev}"
