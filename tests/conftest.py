import os
import sys

# tests must see 1 device by default (the dry-run sets 512 in its own
# process); sharding tests spawn subprocesses with their own XLA_FLAGS.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
