import contextlib
import os
import sys

# tests must see 1 device by default (the dry-run sets 512 in its own
# process); sharding tests spawn subprocesses with their own XLA_FLAGS.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


@contextlib.contextmanager
def compile_events():
    """Collect jax compile-cache events — one per NEW XLA compilation;
    cached executions add nothing. Shared by the recompile-free contract
    tests (test_updates.py, test_compact.py; test_sharding.py carries its
    own copy inside its subprocess scripts)."""
    from jax._src import monitoring
    events: list = []

    def cb(event, **kw):
        if "compile" in event:
            events.append(event)

    monitoring.register_event_listener(cb)
    try:
        yield events
    finally:
        monitoring._unregister_event_listener_by_callback(cb)
