"""Product quantization + ADC tests (paper §2.2/§4.6, Alg. 4/5/8)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import pq as pqmod, updates
from repro.core.config import ProberConfig

CFG = ProberConfig(pq_m=4, pq_kc=16, pq_iters=10)


@pytest.fixture(scope="module")
def fitted():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (600, 32))
    return x, pqmod.fit(x, CFG, key)


def test_shapes(fitted):
    x, pq = fitted
    assert pq.centroids.shape == (4, 16, 8)
    assert pq.codes.shape == (600, 4)
    assert pq.resid.shape == (600,)
    assert float(jnp.sum(pq.counts)) == 600 * 4


def test_codes_are_nearest_centroids(fitted):
    x, pq = fitted
    xs = pqmod.split_subspaces(x, 4)
    again = pqmod.assign(pq.centroids, xs)
    np.testing.assert_array_equal(np.asarray(again), np.asarray(pq.codes))


def test_adc_table_and_distance_consistent(fitted):
    """ADC distance == ||q - reconstruction||^2 exactly (Alg. 5)."""
    x, pq = fitted
    q = x[7] + 0.1
    lut = pqmod.adc_table(pq, q)
    d = pqmod.adc_distance(lut, pq.codes[:50])
    recon = pq.centroids[jnp.arange(4)[None], pq.codes[:50]]  # (50, 4, 8)
    manual = jnp.sum((pqmod.split_subspaces(x[:50] * 0 + q[None], 4)
                      - recon) ** 2, axis=(-1, -2))
    np.testing.assert_allclose(np.asarray(d), np.asarray(manual), rtol=1e-4)


def test_adc_band_property(fitted):
    """Triangle-inequality band: |sqrt(adc) - sqrt(true)| <= resid, always."""
    x, pq = fitted
    q = x[3]
    lut = pqmod.adc_table(pq, q)
    adc = np.asarray(pqmod.adc_distance(lut, pq.codes))
    true = np.asarray(jnp.sum((x - q[None]) ** 2, axis=-1))
    gap = np.abs(np.sqrt(adc) - np.sqrt(true))
    assert (gap <= np.asarray(pq.resid) + 1e-3).all()


def test_adc_approximates_true_distance_structured():
    """On low-intrinsic-dim data (where distances have spread — isotropic
    Gaussians concentrate and defeat any quantizer) ADC correlates
    strongly with true distance."""
    from repro.data import vectors
    key = jax.random.PRNGKey(0)
    x = vectors.make_corpus(key, 2000, 64)
    cfg = ProberConfig(pq_m=16, pq_kc=32, pq_iters=10)
    pq = pqmod.fit(x, cfg, key)
    q = x[3]
    lut = pqmod.adc_table(pq, q)
    adc = np.asarray(pqmod.adc_distance(lut, pq.codes))
    true = np.asarray(jnp.sum((x - q[None]) ** 2, axis=-1))
    assert np.corrcoef(adc, true)[0, 1] > 0.9


def test_update_pq_running_means(fitted):
    """Alg. 8: counts accumulate; centroids move toward the new mass."""
    x, pq = fitted
    key = jax.random.PRNGKey(9)
    x_new = jax.random.normal(key, (200, 32)) + 2.0
    pq2 = updates.update_pq(pq, x_new, jnp.concatenate([x, x_new], axis=0))
    assert pq2.codes.shape == (800, 4)
    assert int(pq2.n_valid) == 800
    assert float(jnp.sum(pq2.counts)) == 800 * 4
    assert pq2.resid.shape == (800,)
    # new points' codes are nearest of the OLD centroids (paper's rule)
    xs = pqmod.split_subspaces(x_new, 4)
    np.testing.assert_array_equal(
        np.asarray(pqmod.assign(pq.centroids, xs)),
        np.asarray(pq2.codes[600:]))


def test_update_pq_residuals_consistent_after_centroid_move(fitted):
    """Regression: the incremental-mean update moves centroids, so EVERY
    live point's stored residual must equal ||x - q(x)|| under the moved
    codebook — old points used to keep pre-update residuals."""
    x, pq = fitted
    x_new = jax.random.normal(jax.random.PRNGKey(5), (150, 32)) + 1.5
    x_all = jnp.concatenate([x, x_new], axis=0)
    pq2 = updates.update_pq(pq, x_new, x_all)
    want = pqmod.reconstruction_residual(
        pq2.centroids, pq2.codes.astype(jnp.int32),
        pqmod.split_subspaces(x_all, pq2.m))
    np.testing.assert_allclose(np.asarray(pq2.resid), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    # and the old points' centroids really did move (the test's premise)
    assert float(jnp.max(jnp.abs(pq2.centroids - pq.centroids))) > 1e-3


def test_update_equivalent_mass():
    """Counts-weighted incremental mean == batch mean when assignments are
    held fixed."""
    key = jax.random.PRNGKey(1)
    x1 = jax.random.normal(key, (100, 8))
    cfg = ProberConfig(pq_m=2, pq_kc=4, pq_iters=5)
    pq1 = pqmod.fit(x1, cfg, key)
    x2 = jax.random.normal(jax.random.PRNGKey(2), (50, 8)) * 0.1
    pq2 = updates.update_pq(pq1, x2, jnp.concatenate([x1, x2], axis=0))
    # manual: c' = (c*n + sum_new)/(n + n_new) per (m, k)
    xs = pqmod.split_subspaces(x2, 2)
    codes = pqmod.assign(pq1.centroids, xs)
    for m in range(2):
        for k in range(4):
            mask = np.asarray(codes[:, m]) == k
            n_old = float(pq1.counts[m, k])
            if mask.sum() == 0:
                np.testing.assert_allclose(np.asarray(pq2.centroids[m, k]),
                                           np.asarray(pq1.centroids[m, k]),
                                           rtol=1e-5)
                continue
            s = np.asarray(xs[:, m][mask]).sum(0)
            want = (np.asarray(pq1.centroids[m, k]) * n_old + s) / (n_old + mask.sum())
            np.testing.assert_allclose(np.asarray(pq2.centroids[m, k]), want,
                                       rtol=1e-4)
