"""Workload-aware estimate cache (DESIGN.md §12).

The contracts under test: exact-repeat hits are BIT-IDENTICAL to the
estimate the original probe produced; any ingest touching a probed bucket
forces a re-probe and NO stale hit is ever served (checked against an
exact shadow tracker over a mixed ingest+query stream, including across
capacity-doubling growth); `reuse_tol` bands tau and relaxes the exact-
query fingerprint; CLOCK eviction prefers cold entries; repeated all-hit
flushes add zero XLA compilations; and flush() reports per-request
provenance.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st
from conftest import compile_events

from repro.core import estimator as E, lsh
from repro.core.config import ProberConfig
from repro.serve.engine import CardinalityCoalescer

CFG = ProberConfig(n_tables=2, n_funcs=6, ring_budget=512,
                   central_budget=512, chunk=128)


@pytest.fixture(scope="module")
def data():
    return np.asarray(jax.random.normal(jax.random.PRNGKey(0), (2048, 16)))


def _coalescer(data, cfg=CFG, n=1024, capacity=4096, cache_size=64,
               reuse_tol=0.0, max_batch=8, seed=0):
    key = jax.random.PRNGKey(seed)
    st_ = E.build(jnp.asarray(data[:n]), cfg, key, capacity=capacity,
                  track_epochs=True)
    return CardinalityCoalescer(st_, cfg, key, max_batch=max_batch,
                                cache_size=cache_size, reuse_tol=reuse_tol)


def test_exact_repeat_hits_bit_identical(data):
    """reuse_tol=0 contract: a repeat of the same (q, tau) is served from
    the cache, bit-identical to what the original probe returned, with
    provenance the caller can audit."""
    co = _coalescer(data)
    qs = [data[i] + 0.01 for i in range(5)]
    taus = [3.0, 4.0, 5.0, 3.5, 4.5]
    first = [co.submit(qs[i], taus[i]) for i in range(5)]
    out0 = co.flush()
    assert all(r.provenance == "probe" for r in first)
    assert all(out0[r.rid].provenance == "probe" for r in first)
    again = [co.submit(qs[i], taus[i]) for i in range(5)]
    out1 = co.flush()
    for a, b in zip(first, again):
        assert b.provenance == "hit"
        assert out1[b.rid].provenance == "hit"
        assert a.est == b.est                      # bit-identical, not close
    assert co.cache_stats["hits"] == 5
    assert co.cache_stats["misses"] == 5
    # a different tau (even slightly) is NOT the same request
    r = co.submit(qs[0], taus[0] + 1e-3)
    co.flush()
    assert r.provenance == "probe"


def test_near_duplicate_query_misses_at_tol_zero(data):
    """reuse_tol=0 is fully strict: a query differing in one float bit of
    one coordinate misses even though its LSH codes collide."""
    co = _coalescer(data)
    q = data[3] + 0.01
    co.submit(q, 4.0)
    co.flush()
    q2 = q.copy()
    q2[0] = np.nextafter(q2[0], np.inf)            # same bucket, new bytes
    r = co.submit(q2, 4.0)
    co.flush()
    assert r.provenance == "probe"


def test_reuse_tol_bands_tau_and_lsh_keys(data):
    """reuse_tol>0: hits extend to the same tau band and to LSH
    near-duplicates (identical codes in every table)."""
    co = _coalescer(data, reuse_tol=0.3)
    q = data[7] + 0.01
    co.submit(q, 5.0)
    co.flush()
    r_band = co.submit(q, 5.5)                     # same (1+0.3) log-band
    co.flush()
    assert r_band.provenance == "hit"
    r_far = co.submit(q, 8.0)                      # different band
    co.flush()
    assert r_far.provenance == "probe"
    # a tiny perturbation keeps all bucket codes -> near-duplicate hit
    q2 = q + 1e-6
    codes_same = np.array_equal(
        np.asarray(lsh.hash_point(co.state.index.params, jnp.asarray(q),
                                  CFG.n_tables)),
        np.asarray(lsh.hash_point(co.state.index.params, jnp.asarray(q2),
                                  CFG.n_tables)))
    r_near = co.submit(q2, 5.0)
    co.flush()
    assert r_near.provenance == ("hit" if codes_same else "probe")


def test_ingest_into_probed_bucket_invalidates(data):
    """Epoch invalidation: an ingest landing AT a cached query's location
    (its central bucket) must force a re-probe whose estimate sees the new
    points."""
    cfg = CFG.replace(ingest_chunk=64)
    co = _coalescer(data, cfg=cfg)
    q = data[0] + 50.0                             # isolated: est ~ 0
    r0 = co.submit(q, 3.0)
    co.flush()
    assert r0.est < 1.0
    cluster = q[None, :] + 0.05 * np.asarray(
        jax.random.normal(jax.random.PRNGKey(1), (128, 16)))
    co.ingest(cluster.astype(np.float32))
    r1 = co.submit(q, 3.0)
    co.flush()
    assert r1.provenance in ("stale-refresh", "probe")
    assert r1.est > 50.0, r1.est                   # the cluster is visible


class _ShadowTracker:
    """Exact mirror of what MAY be served from cache: for every cached key
    it recomputes, from the index itself, whether any ingest since the
    entry's probe landed within the entry's probed rings. A `hit` for a
    dirty key is a stale serve — the property the epoch layer must make
    impossible."""

    def __init__(self, cfg):
        self.cfg = cfg
        self.entries: dict = {}     # (qbytes, tau) -> {probed_k, est, w}

    def _codes(self, state, q):
        return np.asarray(lsh.hash_point(state.index.params,
                                         jnp.asarray(q), self.cfg.n_tables))

    def record_probe(self, state, req):
        assert req.probed_k is not None     # every probe reports its rings
        self.entries[(req.q.tobytes(), req.tau)] = {
            "qcodes": self._codes(state, req.q),
            "w": np.asarray(state.index.params.w).copy(),
            "probed_k": np.asarray(req.probed_k),
            "dirty": False, "est": req.est}

    def note_ingest(self, state_after, x_new):
        new_codes = np.asarray(lsh.hash_point(
            state_after.index.params, jnp.asarray(x_new),
            self.cfg.n_tables))                     # (Nn, L, K)
        w_now = np.asarray(state_after.index.params.w)
        for e in self.entries.values():
            if not np.array_equal(e["w"], w_now):
                e["dirty"] = True                   # geometry changed
                continue
            # distance of each new point's bucket to the entry's code: the
            # entry depends EXACTLY on buckets within its probed rings
            d = (new_codes != e["qcodes"][None]).sum(-1)   # (Nn, L)
            if (d.min(0) <= e["probed_k"]).any():
                e["dirty"] = True

    def check_serve(self, req):
        e = self.entries.get((req.q.tobytes(), req.tau))
        if req.provenance == "hit":
            assert e is not None, "hit without a recorded probe"
            assert not e["dirty"], "STALE SERVE: ingest touched probed rings"
            assert req.est == e["est"], "hit diverged from recorded estimate"


def test_zero_stale_serves_mixed_stream(data):
    """The acceptance property: over a mixed ingest+query stream —
    crossing a capacity doubling — every `hit` the coalescer serves is for
    an entry whose probed rings no ingest has touched (exact shadow
    check), and hits still actually happen (the test is not vacuous)."""
    cfg = CFG.replace(ingest_chunk=64)
    rng = np.random.default_rng(0)
    # capacity == n: the ingest stream forces grow_capacity doublings
    co = _coalescer(data, cfg=cfg, n=1024, capacity=1024, cache_size=128,
                    max_batch=16)
    shadow = _ShadowTracker(cfg)
    qpool = [data[i] + 0.01 for i in range(12)]
    taupool = [3.0, 4.0, 5.0]
    n_hits = 0
    for step in range(30):
        if step % 5 == 4:
            x_new = data[rng.integers(0, 2048, 48)] + \
                0.1 * rng.standard_normal((48, 16)).astype(np.float32)
            co.ingest(x_new)
            co.apply_ingest()
            shadow.note_ingest(co.state, x_new)
        reqs = [co.submit(qpool[rng.integers(len(qpool))],
                          taupool[rng.integers(len(taupool))])
                for _ in range(4)]
        co.flush()
        for r in reqs:
            shadow.check_serve(r)
            if r.provenance == "hit":
                n_hits += 1
            else:
                shadow.record_probe(co.state, r)
    assert int(co.state.n_valid) > 1024            # stream actually grew
    assert co.state.capacity > 1024                # ... through doublings
    assert n_hits > 0, "no hits at all — the property test is vacuous"
    assert co.cache_stats["hits"] == n_hits


def test_entries_survive_growth_without_ingest_overlap(data):
    """Capacity doubling itself must not invalidate entries — epochs key on
    code values, not rows, and W is bitwise-stable when no projection
    extreme moves (lsh.project_raw). Construction: a budget-truncated
    probe (small ``probed_k``), then an ingest of MIDPOINTS of live points
    (convex combinations — provably inside every per-function projection
    range, so Alg. 7 reproduces W exactly) FILTERED to bucket codes
    outside the entry's probed rings. The ingest forces a doubling, yet
    the entry keeps serving bit-identical hits."""
    cfg = CFG.replace(ingest_chunk=64, max_visit=256)   # shallow probes
    co = _coalescer(data, cfg=cfg, n=1024, capacity=1024, max_batch=8)
    q = data[0] + 0.01              # dense region: budget stops the probe
    r0 = co.submit(q, 3.0)
    co.flush()
    assert r0.probed_k is not None and r0.probed_k.max() < CFG.n_funcs, \
        "probe was not truncated — the test needs a small ball"
    epoch0 = int(co.state.epochs.params_epoch)
    mids = 0.5 * (data[:512] + data[512:1024])     # inside all extremes
    qc = np.asarray(lsh.hash_point(co.state.index.params, jnp.asarray(q),
                                   cfg.n_tables))              # (L, K)
    mc = np.asarray(lsh.hash_point(co.state.index.params,
                                   jnp.asarray(mids), cfg.n_tables))
    outside = ((mc != qc[None]).sum(-1) > r0.probed_k[None, :]).all(-1)
    mids = mids[outside]
    assert len(mids) >= 64, "not enough out-of-ball midpoints"
    co.ingest(mids.astype(np.float32))             # forces capacity growth
    co.apply_ingest()
    assert co.state.capacity > 1024
    assert int(co.state.epochs.params_epoch) == epoch0, \
        "W drifted on an ingest that extended no projection extreme"
    r1 = co.submit(q, 3.0)
    co.flush()
    assert r1.provenance == "hit"
    assert r1.est == r0.est


def test_clock_eviction_prefers_cold_entries(data):
    """Second chance: with a 4-entry cache and 4 cached keys, touching one
    key (a hit re-arms its ref bit) then inserting new keys must evict
    among the untouched ones first."""
    co = _coalescer(data, cache_size=4, max_batch=4)
    qs = [data[i] + 0.01 for i in range(7)]
    for i in range(4):
        co.submit(qs[i], 4.0)
        co.flush()
    hot = co.submit(qs[0], 4.0)                    # touch entry 0
    co.flush()
    assert hot.provenance == "hit"
    for i in range(4, 7):                          # 3 insertions, 3 evicts
        co.submit(qs[i], 4.0)
        co.flush()
    assert co.cache_stats["evicts"] == 3
    still_hot = co.submit(qs[0], 4.0)
    co.flush()
    assert still_hot.provenance == "hit", \
        "the touched entry was evicted before the cold ones"


def test_all_hit_flush_zero_recompiles(data):
    """Serving contract: once the flush shapes are warm, an all-hit flush
    (and the lookup partition step of a mixed flush) adds ZERO new XLA
    compilations — the cache hot path is pure cached executables."""
    co = _coalescer(data, max_batch=8)
    qs = [data[i] + 0.01 for i in range(4)]
    for q in qs:
        co.submit(q, 4.0)
    co.flush()                                     # warm probe + insert
    for q in qs:
        co.submit(q, 4.0)
    co.flush()                                     # warm all-hit lookup
    with compile_events() as ev:
        for q in qs:
            co.submit(q, 4.0)
        out = co.flush()
    assert len(out) == 4
    assert all(v.provenance == "hit" for v in out.values())
    assert ev == [], f"all-hit flush recompiled: {ev}"


def test_cached_results_match_uncached_distribution(data):
    """meanQ-preservation mechanism: with no repeats in the stream the
    cached coalescer produces the SAME estimates as an uncached one (the
    cache must not perturb the probe path it wraps)."""
    key = jax.random.PRNGKey(3)
    st_ = E.build(jnp.asarray(data[:1024]), CFG, key, capacity=2048,
                  track_epochs=True)
    a = CardinalityCoalescer(st_, CFG, key, max_batch=8, cache_size=64)
    b = CardinalityCoalescer(st_, CFG, key, max_batch=8)
    qs = [data[i] + 0.01 for i in range(6)]
    ra = [a.submit(q, 4.0) for q in qs]
    rb = [b.submit(q, 4.0) for q in qs]
    a.flush()
    b.flush()
    for x, y in zip(ra, rb):
        assert x.est == y.est


@settings(max_examples=15, deadline=None)
@given(st.integers(min_value=0, max_value=2047),
       st.floats(min_value=0.5, max_value=8.0, allow_nan=False,
                 width=32))
def test_property_repeat_hit_equals_first_serve(idx, tau):
    """Property (hypothesis): for ANY (query, tau), serving the request
    twice yields provenance probe-then-hit with bit-identical estimates."""
    data = np.asarray(jax.random.normal(jax.random.PRNGKey(0), (2048, 16)))
    co = _coalescer(data, cache_size=32, max_batch=4)
    q = data[idx] + 0.01
    r0 = co.submit(q, float(tau))
    co.flush()
    r1 = co.submit(q, float(tau))
    co.flush()
    assert r0.provenance == "probe" and r1.provenance == "hit"
    assert r0.est == r1.est
