"""LSH index unit tests (paper §2.2/§4.2 + sorted-CSR layout invariants)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import lsh
from repro.core.config import ProberConfig

CFG = ProberConfig(n_tables=2, n_funcs=6)


@pytest.fixture(scope="module")
def data():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (500, 16))
    return x, lsh.build_index(x, CFG, key)


def test_codes_shape(data):
    x, idx = data
    assert idx.codes.shape == (2, 500, 6)
    assert idx.raw.shape == (500, 12)


def test_csr_partition_is_exact(data):
    """Every point appears exactly once; buckets partition the dataset."""
    x, idx = data
    for t in range(2):
        order = np.asarray(idx.order[t])
        assert sorted(order.tolist()) == list(range(500))
        nb = int(idx.n_buckets[t])
        sizes = np.asarray(idx.bucket_sizes[t])
        starts = np.asarray(idx.bucket_starts[t])
        assert sizes[:nb].sum() == 500
        assert (sizes[nb:] == 0).all()
        # CSR contiguity
        assert starts[0] == 0
        np.testing.assert_array_equal(starts[1:nb],
                                      np.cumsum(sizes[:nb])[:-1])


def test_bucket_members_share_code(data):
    x, idx = data
    for t in range(2):
        nb = int(idx.n_buckets[t])
        codes = np.asarray(idx.codes[t])
        order = np.asarray(idx.order[t])
        starts = np.asarray(idx.bucket_starts[t])
        sizes = np.asarray(idx.bucket_sizes[t])
        bcodes = np.asarray(idx.bucket_codes[t])
        for j in range(0, nb, max(nb // 20, 1)):
            members = order[starts[j]: starts[j] + sizes[j]]
            for m in members:
                np.testing.assert_array_equal(codes[m], bcodes[j])


def test_bucket_codes_unique(data):
    _, idx = data
    for t in range(2):
        nb = int(idx.n_buckets[t])
        bc = np.asarray(idx.bucket_codes[t][:nb])
        assert len(np.unique(bc, axis=0)) == nb


def test_hash_point_matches_index(data):
    x, idx = data
    codes = lsh.hash_point(idx.params, x[17], CFG.n_tables)
    np.testing.assert_array_equal(np.asarray(codes),
                                  np.asarray(idx.codes[:, 17]))


def test_hamming_rings(data):
    x, idx = data
    qcode = idx.codes[0, 17]
    ham = lsh.hamming_to_buckets(idx.bucket_codes[0], idx.n_buckets[0], qcode)
    ham = np.asarray(ham)
    nb = int(idx.n_buckets[0])
    # the point's own bucket is at distance 0
    assert (ham[:nb] == 0).sum() == 1
    # padding rows can never join a ring
    assert (ham[nb:] == CFG.n_funcs + 1).all()


def test_collision_probability_decreases_with_distance():
    """LSH property (Def. 4): closer pairs collide more."""
    key = jax.random.PRNGKey(1)
    x0 = jax.random.normal(key, (1, 32))
    near = x0 + 0.05 * jax.random.normal(jax.random.PRNGKey(2), (200, 32))
    far = x0 + 3.0 * jax.random.normal(jax.random.PRNGKey(3), (200, 32))
    data = jnp.concatenate([x0, near, far], axis=0)
    cfg = ProberConfig(n_tables=1, n_funcs=8)
    idx = lsh.build_index(data, cfg, key)
    codes = np.asarray(idx.codes[0])
    ham_near = (codes[1:201] != codes[0]).sum(-1)
    ham_far = (codes[201:] != codes[0]).sum(-1)
    assert ham_near.mean() < ham_far.mean()


def test_lexsort_rows_sorted():
    key = jax.random.PRNGKey(4)
    rows = jax.random.randint(key, (300, 5), 0, 4)
    perm = lsh.lexsort_rows(rows)
    s = np.asarray(rows[perm])
    for i in range(1, len(s)):
        assert tuple(s[i - 1]) <= tuple(s[i])


def _check_lexsorted(rows, perm, n_live=None):
    s = np.asarray(rows)[np.asarray(perm)]
    n_live = len(s) if n_live is None else n_live
    for i in range(1, n_live):
        assert tuple(s[i - 1]) <= tuple(s[i]), i


def test_lexsort_packed_fast_path_matches_generic():
    """The rank-compressed single-sort fast path (DESIGN.md §10) must agree
    with the K-pass column sort on every regime: small codes (packed),
    wide/negative codes (fallback), and capacity-masked rows (sentinel keys
    sort last)."""
    key = jax.random.PRNGKey(5)
    # small range incl. negatives -> packed path
    rows = jax.random.randint(key, (257, 7), -3, 4)
    _check_lexsorted(rows, lsh.lexsort_rows(rows))
    # wide range -> fallback path
    wide = jax.random.randint(key, (200, 4), -2**20, 2**20)
    _check_lexsorted(wide, lsh.lexsort_rows(wide))
    # masked rows: live prefix sorted, dead rows all at the tail
    n, n_live = 128, 90
    codes = np.array(jax.random.randint(key, (n, 6), 0, 5))
    codes[n_live:] = lsh.CODE_SENTINEL
    valid = jnp.arange(n) < n_live
    perm = np.asarray(lsh.lexsort_rows(jnp.asarray(codes), valid=valid))
    assert sorted(perm.tolist()) == list(range(n))
    assert set(perm[n_live:].tolist()) == set(range(n_live, n))
    _check_lexsorted(codes, perm, n_live)
