"""Quantized ADC datapath (DESIGN.md §11).

Covers: affine uint8 LUT round-trip error bound, quantized-vs-float
qualification agreement (EXACT outside the ±(M/2+1)·scale rounding band
around tau², never wildly off inside it), packed 4-bit code round-trip and
gather equivalence, the int LUT kernels against their jnp reference, and
end-to-end bitwise batch-vs-sequential equality on the quantized config.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import estimator as E, pq as pqmod, prober
from repro.core.config import ProberConfig
from repro.kernels import adc as adc_mod

CFG = ProberConfig(n_tables=2, n_funcs=6, ring_budget=512,
                   central_budget=512, chunk=128,
                   use_pq=True, pq_m=8, pq_kc=16, pq_iters=4,
                   pq_int8_lut=True)


@pytest.fixture(scope="module")
def data():
    return jax.random.normal(jax.random.PRNGKey(0), (2000, 32))


@pytest.fixture(scope="module")
def state(data):
    return E.build(data, CFG, jax.random.PRNGKey(0))


def test_quantize_lut_roundtrip(state):
    lut = pqmod.adc_table(state.pq, jnp.zeros((32,)) + 0.3)
    q = pqmod.quantize_lut(lut)
    assert q.q8.dtype == jnp.uint8
    deq = np.asarray(q.offset + q.scale * q.q8.astype(jnp.float32))
    err = np.abs(deq - np.asarray(lut))
    assert err.max() <= 0.5 * float(q.scale) * (1 + 1e-5), err.max()


def test_q8_qualification_matches_float_outside_band(state, data):
    """Decisions agree with float32 ADC for every candidate whose float ADC
    distance is farther than (M/2 + 1)·scale from tau² — and the quantized
    decision is EXACT w.r.t. the dequantized distances everywhere."""
    pq = state.pq
    m = pq.m
    ids = jnp.arange(1500)
    for qi in range(4):
        q = data[qi] + 0.01
        lut = pqmod.adc_table(pq, q)
        qlut = pqmod.quantize_lut(lut)
        adc_f = np.asarray(pqmod.adc_distance(lut, pq.codes[ids]
                                              .astype(jnp.int32)))
        # pick tau^2 at a mid quantile so both decisions occur
        tau_sq = jnp.float32(np.quantile(adc_f, 0.4))
        want = adc_f <= float(tau_sq)
        fn = prober.make_adc_qualfn_q8(pq.codes, qlut, tau_sq)
        got = np.asarray(fn(ids)) > 0.5
        band = (m / 2 + 1) * float(qlut.scale)
        away = np.abs(adc_f - float(tau_sq)) > band
        assert away.sum() > 100        # the test actually exercises both sides
        np.testing.assert_array_equal(got[away], want[away])
        # disagreements with float must be confined to the band, and rare
        assert np.all(np.abs(adc_f[got != want] - float(tau_sq)) <= band)
        assert np.mean(got != want) < 0.05


def test_pack4_roundtrip_and_qualfn_equivalence(state, data):
    pq = state.pq
    packed = pqmod.pack_codes(pq.codes)
    assert packed.shape == (pq.codes.shape[0], pq.m // 2)
    np.testing.assert_array_equal(np.asarray(pqmod.unpack_codes(packed)),
                                  np.asarray(pq.codes.astype(jnp.int32)))
    q = data[0] + 0.01
    lut = pqmod.adc_table(pq, q)
    qlut = pqmod.quantize_lut(lut)
    tau_sq = jnp.float32(6.0)
    ids = jnp.arange(777)
    a = prober.make_adc_qualfn_q8(pq.codes, qlut, tau_sq)(ids)
    b = prober.make_adc_qualfn_q8(pq.codes, qlut, tau_sq, packed=packed)(ids)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    c = prober.make_adc_qualfn(pq.codes, lut, tau_sq)(ids)
    d = prober.make_adc_qualfn(pq.codes, lut, tau_sq, packed=packed)(ids)
    np.testing.assert_array_equal(np.asarray(c), np.asarray(d))


def test_adc_q8_kernels_match_reference():
    key = jax.random.PRNGKey(1)
    n, m, kc, q = 777, 8, 32, 5       # n % bn != 0 exercises the padding
    kc_, kl = jax.random.split(key)
    codes = jax.random.randint(kc_, (n, m), 0, kc).astype(jnp.uint8)
    qluts = jax.random.randint(kl, (q, m, kc), 0, 256).astype(jnp.uint8)
    got = adc_mod.adc_batch_q8(codes, qluts, bn=256, interpret=True)
    assert got.shape == (q, n) and got.dtype == jnp.int32
    ref = jnp.stack([
        jnp.sum(qluts[i][jnp.arange(m), codes.astype(jnp.int32)]
                .astype(jnp.int32), axis=-1) for i in range(q)])
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
    single = jnp.stack([adc_mod.adc_q8(codes, qluts[i], bn=256,
                                       interpret=True) for i in range(q)])
    np.testing.assert_array_equal(np.asarray(single), np.asarray(ref))


def test_estimate_batch_bitwise_q8_pack4(data):
    """Batch == sequential bit-for-bit on the full quantized+packed config
    (both route through the same quantized qualfns and PRNG keys)."""
    cfg = CFG.replace(pq_pack4=True)
    st = E.build(data, cfg, jax.random.PRNGKey(0))
    assert st.pq.packed is not None
    qs, taus = data[:6] + 0.01, jnp.linspace(4.0, 9.0, 6)
    key = jax.random.PRNGKey(7)
    keys = jax.random.split(key, 6)
    batch = E.estimate_batch(st, qs, taus, cfg, key)
    seq = jnp.stack([E.estimate(st, qs[i], taus[i], cfg, keys[i])
                     for i in range(6)])
    np.testing.assert_array_equal(np.asarray(batch), np.asarray(seq))
    assert np.asarray(batch).std() > 0


def test_q8_accuracy_close_to_float(data):
    """End-to-end: quantized-datapath estimates stay close to the float-ADC
    estimates (same index, same keys) — the LUT rounding band only moves
    candidates whose distance is within ~M·scale/2 of tau²."""
    cfg_f = CFG.replace(pq_int8_lut=False)
    st_f = E.build(data, cfg_f, jax.random.PRNGKey(0))
    st_q = E.build(data, CFG, jax.random.PRNGKey(0))
    qs, taus = data[:6] + 0.01, jnp.linspace(4.0, 9.0, 6)
    key = jax.random.PRNGKey(7)
    f = np.asarray(E.estimate_batch(st_f, qs, taus, cfg_f, key))
    qv = np.asarray(E.estimate_batch(st_q, qs, taus, CFG, key))
    ref = np.maximum(f, 10.0)
    assert np.all(np.abs(qv - f) <= 0.25 * ref + 1e-6), (qv, f)


def test_pq_ingest_maintains_packed(data):
    """Dynamic updates (Alg. 8) keep the 4-bit mirror in sync with the byte
    codes across in-capacity ingests."""
    cfg = CFG.replace(pq_pack4=True)
    st = E.build(data[:1024], cfg, jax.random.PRNGKey(0), capacity=2048)
    st = E.update(st, data[1024:1280], cfg)
    assert st.pq.packed is not None
    nv = int(st.n_valid)
    np.testing.assert_array_equal(
        np.asarray(pqmod.unpack_codes(st.pq.packed[:nv])),
        np.asarray(st.pq.codes[:nv].astype(jnp.int32)))
