"""Neighbor lookup table (Alg. 6/9) vs the online Hamming path — the two
must produce identical rings; updates must equal a fresh build."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core import lsh, neighbors


def _codes(key, b, k, vals=4):
    return jax.random.randint(key, (b, k), 0, vals)


def test_table_matches_online_rings():
    key = jax.random.PRNGKey(0)
    codes = _codes(key, 40, 6)
    # dedupe rows to mimic unique bucket codes
    codes = jnp.asarray(np.unique(np.asarray(codes), axis=0))
    b = codes.shape[0]
    table = neighbors.build(codes, jnp.int32(b), max_dist=6)
    for i in (0, 1, b // 2):
        ham = lsh.hamming_to_buckets(codes, jnp.int32(b), codes[i])
        for k in range(1, 7):
            online = np.asarray(ham == k)
            tab = np.asarray(neighbors.ring(table, jnp.int32(i), jnp.int32(k)))
            np.testing.assert_array_equal(online, tab, err_msg=f"i={i} k={k}")


def test_table_respects_max_dist():
    key = jax.random.PRNGKey(1)
    codes = jnp.asarray(np.unique(np.asarray(_codes(key, 30, 8)), axis=0))
    b = codes.shape[0]
    table = neighbors.build(codes, jnp.int32(b), max_dist=3)
    d = np.asarray(table.dists)
    assert d.max() <= 3
    assert (np.diag(d) == 0).all()


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000), n_new=st.integers(1, 10))
def test_incremental_update_equals_fresh_build(seed, n_new):
    """Alg. 9 == Alg. 6 on the concatenated code set (property test)."""
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    old = np.unique(np.asarray(_codes(k1, 25, 5)), axis=0)
    new = np.asarray(_codes(k2, n_new, 5))
    both = np.concatenate([old, new], axis=0)
    n_old, n_all = len(old), len(both)
    table_old = neighbors.build(jnp.asarray(old), jnp.int32(n_old), max_dist=4)
    updated = neighbors.update(table_old, jnp.asarray(both),
                               jnp.int32(n_old), jnp.int32(n_all))
    fresh = neighbors.build(jnp.asarray(both), jnp.int32(n_all), max_dist=4)
    np.testing.assert_array_equal(np.asarray(updated.dists),
                                  np.asarray(fresh.dists))
