"""Sharding-rule unit tests + 8-device CPU integration tests (subprocess so
the forced device count doesn't leak into other tests).

All mesh construction goes through ``repro.compat`` so the tests run on the
pinned jax 0.4.37 (no ``jax.sharding.AxisType``, ``shard_map`` still in
``jax.experimental``) as well as on current jax.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs, compat
from repro.launch import specs as S


def _run(code: str, timeout: int = 480):
    return subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True,
                          env={**os.environ, "PYTHONPATH": "src"},
                          cwd=os.path.dirname(os.path.dirname(__file__)),
                          timeout=timeout)


def test_cell_support_matrix():
    cfg_dense = configs.get_config("qwen2-7b")
    ok, why = S.cell_supported(cfg_dense, "long_500k")
    assert not ok and "sub-quadratic" in why
    for arch in ("rwkv6-1.6b", "recurrentgemma-9b"):
        ok, _ = S.cell_supported(configs.get_config(arch), "long_500k")
        assert ok
    for shape in ("train_4k", "prefill_32k", "decode_32k"):
        for arch in configs.ARCHS:
            ok, _ = S.cell_supported(configs.get_config(arch), shape)
            assert ok


def test_sharded_paths_on_trivial_mesh():
    """The whole distributed surface on a 1-device mesh (fast, in-process):
    build/update/estimate run, sync == local == the single-device batched
    path bit-for-bit (one shard pools only with itself)."""
    from repro.core import distributed as D, estimator as E
    from repro.core.config import ProberConfig
    mesh = compat.make_mesh((1,), ("data",))
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (2000, 16))
    cfg = ProberConfig(n_tables=2, n_funcs=6, ring_budget=512,
                       central_budget=512, chunk=128)
    state, params = D.build_sharded(x[:1000], cfg, key, mesh, capacity=4096)
    nv = None
    for i in range(1000, 2000, 250):
        state, nv = D.update_sharded(state, np.asarray(x[i:i + 250]), cfg,
                                     mesh, n_valid=nv)
    assert nv.tolist() == [2000]
    qs, taus = x[:4] + 0.01, jnp.linspace(3.0, 6.0, 4)
    got_l = D.estimate_sharded(state, qs, taus, cfg, key, mesh, mode="local")
    got_s = D.estimate_sharded(state, qs, taus, cfg, key, mesh, mode="sync")
    # reference: the local shard state through the plain batched path with
    # the same per-shard folded key
    st_local = jax.tree_util.tree_map(lambda a: a[0], state)
    want = E.estimate_batch(st_local, qs, taus, cfg,
                            jax.random.fold_in(key, 0))
    np.testing.assert_array_equal(np.asarray(got_l), np.asarray(want))
    np.testing.assert_array_equal(np.asarray(got_s), np.asarray(want))


def test_param_specs_divisibility_fallback():
    """whisper vocab 51865 %16 != 0 -> embedding replicated, never an error."""
    code = """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax
        from jax.sharding import PartitionSpec as P
        from repro import configs, compat
        from repro.launch import specs as S
        from repro.sharding import rules
        mesh = compat.make_mesh((2, 4), ("data", "model"))
        cfg = configs.get_config("whisper-medium")
        params = S.param_specs_for(cfg)
        specs = rules.param_specs(params, mesh, "fsdp_tp")
        emb = specs["embed"]["embedding"]
        assert emb[0] is None, emb      # 51865 % 4 != 0 -> replicated
        cfg2 = configs.get_config("olmo-1b")
        specs2 = rules.param_specs(S.param_specs_for(cfg2), mesh, "fsdp_tp")
        assert specs2["embed"]["embedding"] == P("model", "data")
        wq = specs2["layers"]["attn"]["wq"]
        assert wq == P(None, "data", "model"), wq
        print("OK")
    """
    r = _run(code)
    assert "OK" in r.stdout, r.stdout + r.stderr


@pytest.mark.slow
def test_8dev_train_step_parity():
    """The sharded train step must match single-device numerics."""
    code = """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro import configs
        from repro.launch.train import build_trainer
        from repro.launch.mesh import make_host_mesh
        from repro.optim import adamw
        from repro.models import get_family
        from repro.train.step import make_train_step

        cfg = configs.get_smoke_config("olmo-1b")
        opt_cfg = adamw.AdamWConfig(lr=1e-2, warmup_steps=1, total_steps=10)
        fam = get_family(cfg)
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, cfg.vocab)}
        batch["labels"] = batch["tokens"]

        # single-device reference: loss + grads (adam's step-1 update is
        # ~sign(g), ill-conditioned to reduction-order noise, so we compare
        # the gradients themselves)
        params = fam.init(jax.random.PRNGKey(0), cfg)
        loss_fn = lambda p, b: fam.loss_fn(p, b, cfg)
        l_ref, g_ref = jax.jit(jax.value_and_grad(loss_fn))(params, batch)

        # 8-device (2 data x 4 model); grads BEFORE the step (params donated)
        mesh = make_host_mesh(model=4)
        p, o, jitted = build_trainer(cfg, mesh, opt_cfg)
        l_sh, g_sh = jax.jit(jax.value_and_grad(loss_fn))(p, batch)
        p2, o2, m = jitted(p, o, batch)
        assert abs(float(m["loss"]) - float(l_ref)) < 1e-3, \\
            (float(m["loss"]), float(l_ref))
        gn_ref = adamw.global_norm(g_ref)
        gn_sh = adamw.global_norm(g_sh)
        assert abs(float(gn_ref) - float(gn_sh)) / float(gn_ref) < 2e-2
        w_ref = np.asarray(g_ref["layers"]["mlp"]["wi"])
        w_got = np.asarray(jax.device_get(g_sh["layers"]["mlp"]["wi"]))
        np.testing.assert_allclose(w_got, w_ref, rtol=0.1, atol=1e-2)
        print("OK parity")
    """
    r = _run(code)
    assert "OK parity" in r.stdout, r.stdout[-2000:] + r.stderr[-2000:]


@pytest.mark.slow
def test_8dev_distributed_estimator():
    """psum'd sharded prober == additive over shards (exact-mode check),
    in BOTH stopping modes: with eps=0/s1=1 every ring is exhausted, so
    local and pooled-sync stopping must each recover the exact count."""
    code = """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        from repro import compat
        from repro.core.config import ProberConfig
        from repro.core import estimator as E, distributed as D
        mesh = compat.make_mesh((8,), ("data",))
        key = jax.random.PRNGKey(0)
        x = jax.random.normal(key, (4000, 32))
        cfg = ProberConfig(n_tables=1, n_funcs=6, ring_budget=1024,
                           central_budget=1024, chunk=128, eps=0.0, s1=1.0,
                           max_visit=100000)
        state, params = D.build_sharded(x, cfg, key, mesh)
        qs = x[:3] + 0.01
        taus = jnp.array([1.0, 3.0, 6.0])
        for mode in ("local", "sync"):
            est = D.estimate_sharded(state, qs, taus, cfg, key, mesh,
                                     mode=mode)
            for i in range(3):
                truth = float(E.true_cardinality(x, qs[i], taus[i]))
                got = float(est[i])
                assert abs(got - truth) < 1e-2, (mode, i, got, truth)
        print("OK distributed")
    """
    r = _run(code)
    assert "OK distributed" in r.stdout, r.stdout[-2000:] + r.stderr[-2000:]


@pytest.mark.slow
def test_8dev_sharded_ingest_recompile_free():
    """DESIGN.md §10 extended to the sharded index: after the first chunk
    compiles the shard_map ingest step, further in-capacity round-robin
    updates (and estimates between them) trigger ZERO new XLA compilations,
    per-shard live counts stay balanced, W stays globally consistent, and
    the post-ingest exact-mode estimate matches the ground truth."""
    code = """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from jax._src import monitoring
        from repro import compat
        from repro.core.config import ProberConfig
        from repro.core import estimator as E, distributed as D
        mesh = compat.make_mesh((8,), ("data",))
        key = jax.random.PRNGKey(0)
        x = jax.random.normal(key, (4000, 32))
        cfg = ProberConfig(n_tables=1, n_funcs=6, ring_budget=1024,
                           central_budget=1024, chunk=128, eps=0.0, s1=1.0,
                           max_visit=100000)
        state, params = D.build_sharded(x[:2000], cfg, key, mesh,
                                        capacity=16384)
        qs = x[:3] + 0.01
        taus = jnp.array([1.0, 3.0, 6.0])
        # warm the ingest and estimate steps once
        state, nv = D.update_sharded(state, np.asarray(x[2000:2400]), cfg,
                                     mesh)
        D.estimate_sharded(state, qs, taus, cfg, key, mesh)
        events = []
        def cb(event, **kw):
            if "compile" in event:
                events.append(event)
        monitoring.register_event_listener(cb)
        state, nv = D.update_sharded(state, np.asarray(x[2400:2800]), cfg,
                                     mesh, n_valid=nv)
        state, nv = D.update_sharded(state, np.asarray(x[2800:3200]), cfg,
                                     mesh, n_valid=nv)
        est = D.estimate_sharded(state, qs, taus, cfg, key, mesh)
        monitoring._unregister_event_listener_by_callback(cb)
        assert events == [], f"sharded in-capacity ingest recompiled: "\\
            f"{events}"
        assert nv.tolist() == [400] * 8, nv          # round-robin balance
        w = np.asarray(jax.device_get(state.index.params.w))
        assert np.allclose(w, w[0:1]), "per-shard W diverged"
        for i in range(3):
            truth = float(E.true_cardinality(x[:3200], qs[i], taus[i]))
            assert abs(float(est[i]) - truth) < 1e-2, (i, float(est[i]),
                                                       truth)
        print("OK sharded ingest")
    """
    r = _run(code)
    assert "OK sharded ingest" in r.stdout, r.stdout[-2000:] + r.stderr[-2000:]


@pytest.mark.slow
def test_8dev_sync_beats_local_on_skewed_shards():
    """Pooled-stopping parity (DESIGN.md §4): on a skewed shard split —
    query-cluster mass on shard 0, sparse far-ring matches behind large
    unqualified near rings on shards 1-7 — local per-shard ε-stopping PTFs
    early and truncates the scattered matches, while the sync mode's pooled
    statistics keep the global selectivity above ε and keep probing. Sync
    mean q-error must be <= local mean q-error (fully seeded run)."""
    code = """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from repro import compat
        from repro.core.config import ProberConfig
        from repro.core import estimator as E, distributed as D
        mesh = compat.make_mesh((8,), ("data",))
        rng = np.random.default_rng(0)
        d, S, n_shard, tau, n_sp = 16, 8, 1000, 3.0, 10
        def shell(n, r_lo, r_hi):
            v = rng.normal(size=(n, d))
            v /= np.linalg.norm(v, axis=1, keepdims=True)
            return (v * rng.uniform(r_lo, r_hi, size=(n, 1))
                    ).astype(np.float32)
        # shard 0: the query cluster; shards 1-7: a shell just outside tau
        # (big unqualified near rings) + n_sp true matches just inside tau
        # (they land in deeper rings)
        parts = [shell(n_shard, 0.0, tau * 1.05)]
        for s in range(1, S):
            parts.append(np.concatenate(
                [shell(n_shard - n_sp, tau * 1.05, tau * 1.35),
                 shell(n_sp, tau * 0.80, tau * 0.98)]))
        x = jnp.asarray(np.concatenate(parts))
        key = jax.random.PRNGKey(0)
        cfg = ProberConfig(n_tables=1, n_funcs=8, n_regions=4,
                           ring_budget=2048, central_budget=2048, chunk=64,
                           s1=0.05, eps=0.12)
        state, params = D.build_sharded(x, cfg, key, mesh)
        qs = jnp.asarray(np.tile(np.zeros(d, np.float32), (6, 1)) +
                         0.01 * rng.standard_normal((6, d)).astype(np.float32))
        taus = jnp.full((6,), tau)
        tr = np.asarray([float(E.true_cardinality(x, qs[i], taus[i]))
                         for i in range(6)])
        el = np.asarray(D.estimate_sharded(state, qs, taus, cfg, key, mesh,
                                           mode="local"))
        es = np.asarray(D.estimate_sharded(state, qs, taus, cfg, key, mesh,
                                           mode="sync"))
        def qe(e, t):
            e, t = max(e, 1.0), max(t, 1.0)
            return max(e / t, t / e)
        mq_l = np.mean([qe(el[i], tr[i]) for i in range(6)])
        mq_s = np.mean([qe(es[i], tr[i]) for i in range(6)])
        print(f"mq_local={mq_l:.4f} mq_sync={mq_s:.4f}")
        assert mq_s <= mq_l + 1e-6, (mq_s, mq_l)
        # and sync must actually be accurate, not just relatively better
        assert mq_s < 1.05, mq_s
        print("OK sync parity")
    """
    r = _run(code)
    assert "OK sync parity" in r.stdout, r.stdout[-2000:] + r.stderr[-2000:]
