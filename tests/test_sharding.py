"""Sharding-rule unit tests + an 8-device CPU integration test (subprocess so
the forced device count doesn't leak into other tests)."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import pytest

from repro import configs
from repro.launch import specs as S


def test_cell_support_matrix():
    cfg_dense = configs.get_config("qwen2-7b")
    ok, why = S.cell_supported(cfg_dense, "long_500k")
    assert not ok and "sub-quadratic" in why
    for arch in ("rwkv6-1.6b", "recurrentgemma-9b"):
        ok, _ = S.cell_supported(configs.get_config(arch), "long_500k")
        assert ok
    for shape in ("train_4k", "prefill_32k", "decode_32k"):
        for arch in configs.ARCHS:
            ok, _ = S.cell_supported(configs.get_config(arch), shape)
            assert ok


def test_param_specs_divisibility_fallback():
    """whisper vocab 51865 %16 != 0 -> embedding replicated, never an error."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax
        from jax.sharding import PartitionSpec as P
        from repro import configs
        from repro.launch import specs as S
        from repro.sharding import rules
        mesh = jax.make_mesh((2, 4), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
        cfg = configs.get_config("whisper-medium")
        params = S.param_specs_for(cfg)
        specs = rules.param_specs(params, mesh, "fsdp_tp")
        emb = specs["embed"]["embedding"]
        assert emb[0] is None, emb      # 51865 % 4 != 0 -> replicated
        cfg2 = configs.get_config("olmo-1b")
        specs2 = rules.param_specs(S.param_specs_for(cfg2), mesh, "fsdp_tp")
        assert specs2["embed"]["embedding"] == P("model", "data")
        wq = specs2["layers"]["attn"]["wq"]
        assert wq == P(None, "data", "model"), wq
        print("OK")
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env={**os.environ,
                                        "PYTHONPATH": "src"},
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "OK" in r.stdout, r.stdout + r.stderr


@pytest.mark.slow
def test_8dev_train_step_parity():
    """The sharded train step must match single-device numerics."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro import configs
        from repro.launch.train import build_trainer
        from repro.launch.mesh import make_host_mesh
        from repro.optim import adamw
        from repro.models import get_family
        from repro.train.step import make_train_step

        cfg = configs.get_smoke_config("olmo-1b")
        opt_cfg = adamw.AdamWConfig(lr=1e-2, warmup_steps=1, total_steps=10)
        fam = get_family(cfg)
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, cfg.vocab)}
        batch["labels"] = batch["tokens"]

        # single-device reference: loss + grads (adam's step-1 update is
        # ~sign(g), ill-conditioned to reduction-order noise, so we compare
        # the gradients themselves)
        params = fam.init(jax.random.PRNGKey(0), cfg)
        loss_fn = lambda p, b: fam.loss_fn(p, b, cfg)
        l_ref, g_ref = jax.jit(jax.value_and_grad(loss_fn))(params, batch)

        # 8-device (2 data x 4 model); grads BEFORE the step (params donated)
        mesh = make_host_mesh(model=4)
        p, o, jitted = build_trainer(cfg, mesh, opt_cfg)
        l_sh, g_sh = jax.jit(jax.value_and_grad(loss_fn))(p, batch)
        p2, o2, m = jitted(p, o, batch)
        assert abs(float(m["loss"]) - float(l_ref)) < 1e-3, \
            (float(m["loss"]), float(l_ref))
        gn_ref = adamw.global_norm(g_ref)
        gn_sh = adamw.global_norm(g_sh)
        assert abs(float(gn_ref) - float(gn_sh)) / float(gn_ref) < 2e-2
        w_ref = np.asarray(g_ref["layers"]["mlp"]["wi"])
        w_got = np.asarray(jax.device_get(g_sh["layers"]["mlp"]["wi"]))
        np.testing.assert_allclose(w_got, w_ref, rtol=0.1, atol=1e-2)
        print("OK parity")
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env={**os.environ, "PYTHONPATH": "src"},
                       cwd=os.path.dirname(os.path.dirname(__file__)),
                       timeout=480)
    assert "OK parity" in r.stdout, r.stdout[-2000:] + r.stderr[-2000:]


@pytest.mark.slow
def test_8dev_distributed_estimator():
    """psum'd sharded prober == additive over shards (exact-mode check)."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        from repro.core.config import ProberConfig
        from repro.core import estimator as E, distributed as D
        mesh = jax.make_mesh((8,), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        key = jax.random.PRNGKey(0)
        x = jax.random.normal(key, (4000, 32))
        cfg = ProberConfig(n_tables=1, n_funcs=6, ring_budget=1024,
                           central_budget=1024, chunk=128, eps=0.0, s1=1.0,
                           max_visit=100000)
        state, params = D.build_sharded(x, cfg, key, mesh)
        qs = x[:3] + 0.01
        taus = jnp.array([1.0, 3.0, 6.0])
        est = D.estimate_sharded(state, qs, taus, cfg, key, mesh)
        for i in range(3):
            truth = float(E.true_cardinality(x, qs[i], taus[i]))
            got = float(est[i])
            assert abs(got - truth) < 1e-2, (i, got, truth)
        print("OK distributed")
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env={**os.environ, "PYTHONPATH": "src"},
                       cwd=os.path.dirname(os.path.dirname(__file__)),
                       timeout=480)
    assert "OK distributed" in r.stdout, r.stdout[-2000:] + r.stderr[-2000:]
