"""End-to-end estimator tests (Alg. 1/2/3) + accuracy envelopes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import estimator as E, prober, lsh
from repro.core.config import ProberConfig
from repro.data import vectors

CFG = ProberConfig(n_tables=2, n_funcs=8, ring_budget=1024,
                   central_budget=1024, chunk=128)


@pytest.fixture(scope="module")
def ds():
    return vectors.load("sift", n_queries=4, scale=0.2)   # N=8000, d=128


@pytest.fixture(scope="module")
def state(ds):
    return E.build(ds.x, CFG, jax.random.PRNGKey(0))


def test_estimates_track_truth(ds, state):
    qerrs = []
    for qi in range(4):
        ests = E.estimate_batch(
            state, jnp.tile(ds.queries[qi][None], (ds.taus.shape[1], 1)),
            ds.taus[qi], CFG, jax.random.PRNGKey(qi))
        for t in range(ds.taus.shape[1]):
            e = max(float(ests[t]), 1.0)
            c = max(float(ds.cards[qi, t]), 1.0)
            qerrs.append(max(e / c, c / e))
    assert np.mean(qerrs) < 2.0          # paper-grade accuracy envelope
    assert np.max(qerrs) < 30.0


def test_estimate_nonnegative_and_bounded(ds, state):
    n = ds.x.shape[0]
    est = E.estimate(state, ds.queries[0], jnp.float32(1e6), CFG,
                     jax.random.PRNGKey(0))
    assert 0 <= float(est) <= n * 1.05   # whole-space query ~= N


def test_zero_radius(ds, state):
    est = E.estimate(state, ds.queries[0] + 100.0, jnp.float32(1e-6), CFG,
                     jax.random.PRNGKey(0))
    assert float(est) == 0.0


def test_gather_ring_budget_and_validity(ds, state):
    idx = state.index
    view = jax.tree_util.tree_map(lambda a: a[0], prober.table_views(idx))
    qcode = idx.codes[0, 5]
    ham = lsh.hamming_to_buckets(view.bucket_codes, view.n_buckets, qcode)
    ids, valid, total = prober.gather_ring(view, ham == 1, 256)
    ids, valid, total = map(np.asarray, (ids, valid, total))
    assert ids.shape == (256,)
    assert valid.sum() == min(total, 256)
    # gathered ids must actually belong to ring-1 buckets
    codes = np.asarray(idx.codes[0])
    q = np.asarray(qcode)
    for pid in ids[valid]:
        assert (codes[pid] != q).sum() == 1


def test_ring_gather_full_coverage_small():
    """With a budget >= N, ring gathering is an exact partition."""
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (200, 8))
    cfg = ProberConfig(n_tables=1, n_funcs=4, ring_budget=256,
                       central_budget=256, chunk=64)
    st = E.build(x, cfg, key)
    view = jax.tree_util.tree_map(lambda a: a[0],
                                  prober.table_views(st.index))
    qcode = st.index.codes[0, 0]
    ham = lsh.hamming_to_buckets(view.bucket_codes, view.n_buckets, qcode)
    seen = []
    for k in range(0, 5):
        ids, valid, total = prober.gather_ring(view, ham == k, 256)
        assert int(total) == int(np.asarray(valid).sum())
        seen.extend(np.asarray(ids)[np.asarray(valid)].tolist())
    assert sorted(seen) == list(range(200))


def test_exact_mode_equals_bruteforce_when_budgets_cover():
    """eps=0 + full budgets + s_max=1 => the estimator IS brute force."""
    key = jax.random.PRNGKey(4)
    x = jax.random.normal(key, (300, 16))
    cfg = ProberConfig(n_tables=1, n_funcs=4, ring_budget=512,
                       central_budget=512, chunk=128, eps=0.0, s1=1.0,
                       max_visit=10_000)
    st = E.build(x, cfg, key)
    q = x[0] + 0.01
    for tau in (0.5, 2.0, 5.0):
        truth = float(E.true_cardinality(x, q, jnp.float32(tau)))
        est = float(E.estimate(st, q, jnp.float32(tau), cfg,
                               jax.random.PRNGKey(1)))
        assert abs(est - truth) < 1e-3, (tau, est, truth)


def test_pq_mode_runs(ds):
    cfg = CFG.replace(use_pq=True, pq_m=16, pq_kc=32, pq_iters=6)
    st = E.build(ds.x, cfg, jax.random.PRNGKey(0))
    est = E.estimate(st, ds.queries[0], ds.taus[0, 5], cfg,
                     jax.random.PRNGKey(1))
    c = float(ds.cards[0, 5])
    assert 0 <= float(est) <= ds.x.shape[0]
    assert max(float(est), 1) / max(c, 1) < 50 and \
        max(c, 1) / max(float(est), 1) < 50


def test_updates_preserve_accuracy(ds):
    """Paper §5/Fig. 7: build on 30%, update with 70% ~ static build."""
    n = ds.x.shape[0]
    n0 = int(n * 0.3) // 4 * 4
    st = E.build(ds.x[:n0], CFG, jax.random.PRNGKey(0))
    st = E.update(st, ds.x[n0:], CFG)
    assert int(st.index.n_valid) == n
    assert st.index.capacity >= n
    qerrs = []
    for qi in range(4):
        for t in range(0, ds.taus.shape[1], 3):
            est = E.estimate(st, ds.queries[qi], ds.taus[qi, t], CFG,
                             jax.random.PRNGKey(t))
            e = max(float(est), 1.0)
            c = max(float(ds.cards[qi, t]), 1.0)
            qerrs.append(max(e / c, c / e))
    assert np.mean(qerrs) < 3.0
