"""Deterministic synthetic LM token pipeline.

Production-shaped: sharded by host, stateful cursor (checkpointable), strict
determinism (batch t is a pure function of (seed, step) so restarts and
elastic resharding reproduce the same global stream), backpressure-free
prefetch (synthesis is compute-trivial).

Sequences are Zipf-distributed token draws with Markov bigram structure so
the CE loss actually decreases during the example runs (pure uniform noise
would pin loss at log V).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class TokenPipeline:
    vocab: int
    batch: int              # global batch
    seq: int
    seed: int = 0
    step: int = 0           # cursor — saved/restored by the checkpointer

    def state_dict(self) -> dict:
        return {"seed": self.seed, "step": self.step}

    def load_state_dict(self, st: dict) -> None:
        self.seed = int(st["seed"])
        self.step = int(st["step"])

    def _batch_at(self, step: int) -> dict:
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        k1, k2 = jax.random.split(key)
        # zipf-ish marginal via exponential quantization
        u = jax.random.uniform(k1, (self.batch, self.seq))
        z = jnp.floor(-jnp.log(1 - u) * (self.vocab / 8.0))
        base = jnp.clip(z, 0, self.vocab - 1).astype(jnp.int32)
        # bigram structure: each odd position is its preceding even token
        # plus a per-sequence shift (so CE loss has learnable structure)
        shift = jax.random.randint(k2, (self.batch, 1), 1, 17)
        prev = jnp.roll(base, 1, axis=1)
        dep = (prev + shift) % self.vocab
        tokens = jnp.where((jnp.arange(self.seq) % 2 == 1)[None, :],
                           dep, base)
        return {"tokens": tokens, "labels": tokens}

    def next(self) -> dict:
        b = self._batch_at(self.step)
        self.step += 1
        return b
