"""Synthetic vector corpora shaped like the paper's five datasets.

The container is offline, so SIFT/GloVe/FastText/GIST/YouTube are replaced by
*matched-shape surrogates* (DESIGN.md §8): ambient dimension matches the real
corpus; N is scaled to the CPU budget; the geometry is a clustered **low
intrinsic dimensional manifold** (real image/text embeddings have intrinsic
dim ~8–20), which gives broad distance distributions — unlike isotropic
Gaussians whose distances concentrate in a thin shell and defeat every
approximate method (including the paper's).

Query workload follows the paper's protocol (§6.1 Query Selection): sample
query points from the data, pick a geometric sequence of target cardinalities
in [1, min(20000, 1% N)], and set tau per (query, target) as the minimal
threshold achieving that cardinality (computed from exact sorted distances).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

# name -> (n_objects, dim) at benchmark scale (real-corpus dims, CPU-scaled N)
CORPORA: Dict[str, tuple[int, int]] = {
    "sift":     (40000, 128),
    "glove":    (40000, 300),
    "fasttext": (40000, 300),
    "gist":     (20000, 960),
    "youtube":  (10000, 1770),
}


@dataclasses.dataclass(frozen=True)
class VectorDataset:
    name: str
    x: jax.Array            # (N, d)
    queries: jax.Array      # (Q, d)
    taus: jax.Array         # (Q, T) threshold grid per query
    cards: jax.Array        # (Q, T) exact cardinality per (query, tau)


def make_corpus(key: jax.Array, n: int, dim: int, *, n_clusters: int = 32,
                intrinsic_dim: int = 12, noise: float = 0.05) -> jax.Array:
    """Clustered low-intrinsic-dim manifold embedded in R^dim."""
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    basis = jax.random.normal(k1, (intrinsic_dim, dim)) / np.sqrt(intrinsic_dim)
    centers = jax.random.normal(k2, (n_clusters, intrinsic_dim)) * 2.0
    # heavy-tailed per-cluster scales (paper datasets are highly non-uniform)
    scales = jnp.exp(jax.random.normal(k3, (n_clusters,)) * 0.8)
    assign = jax.random.randint(k4, (n,), 0, n_clusters)
    z = centers[assign] + jax.random.normal(k5, (n, intrinsic_dim)) * scales[assign, None]
    x = z @ basis
    x = x + jax.random.normal(k1, (n, dim)) * noise   # ambient noise
    return x.astype(jnp.float32)


def paper_query_workload(key: jax.Array, x: jax.Array, n_queries: int,
                         n_taus: int = 12, max_card: int | None = None):
    """Paper §6.1: geometric target-cardinality grid, tau = minimal threshold.

    Returns (queries (Q,d), taus (Q,T), cards (Q,T)).
    """
    n = x.shape[0]
    if max_card is None:
        max_card = min(20000, max(n // 100, 2))
    qidx = jax.random.choice(key, n, (n_queries,), replace=False)
    queries = x[qidx]
    targets = np.unique(np.geomspace(1, max_card, n_taus).astype(np.int64))
    targets_j = jnp.asarray(targets)

    @jax.jit
    def taus_for(q):
        d2 = jnp.sum((x - q[None, :]) ** 2, axis=-1)
        d2s = jnp.sort(d2)
        # minimal tau reaching each target cardinality; midpoint to the next
        # distinct distance so ties don't flip the exact count
        at = jnp.sqrt(d2s[targets_j - 1])
        nxt = jnp.sqrt(d2s[jnp.minimum(targets_j, n - 1)])
        return jnp.where(targets_j < n, 0.5 * (at + nxt), at + 1e-3)

    taus = jax.lax.map(taus_for, queries)

    @jax.jit
    def card_for(q, ts):
        d2 = jnp.sum((x - q[None, :]) ** 2, axis=-1)
        return jnp.sum(d2[None, :] <= (ts ** 2)[:, None], axis=-1)

    cards = jax.lax.map(lambda qt: card_for(qt[0], qt[1]), (queries, taus))
    return queries, taus, cards


def load(name: str, key: jax.Array | None = None, n_queries: int = 32,
         scale: float = 1.0) -> VectorDataset:
    """Build a named surrogate corpus + paper-protocol query workload."""
    if key is None:
        key = jax.random.PRNGKey(hash(name) % (2 ** 31))
    n, dim = CORPORA[name]
    n = int(n * scale)
    kx, kq = jax.random.split(key)
    x = make_corpus(kx, n, dim)
    queries, taus, cards = paper_query_workload(kq, x, n_queries)
    return VectorDataset(name=name, x=x, queries=queries, taus=taus, cards=cards)
