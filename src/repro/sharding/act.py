"""Activation sharding constraints (MaxText-style).

GSPMD left alone tends to pick contracting-dim strategies for FSDP-sharded
weights (activations replicated over batch, giant per-layer all-reduces —
measured 831 GiB/device on olmo-1b before constraints, DESIGN.md §7).
Pinning activations to batch-sharded layouts at layer boundaries forces the
ZeRO-style weight all-gather strategy instead.

Models call ``constrain(x)`` at layer boundaries; launchers enable it with
``with activation_sharding(("pod", "data")): ...`` around trace time. A
no-op when unset, so small-scale tests/examples are unaffected.
"""
from __future__ import annotations

import contextlib
import contextvars

import jax
from jax.sharding import PartitionSpec as P

_CTX: contextvars.ContextVar = contextvars.ContextVar(
    "repro_act_sharding", default=None)


@contextlib.contextmanager
def activation_sharding(mesh, batch_axes):
    """batch_axes: mesh axis names the batch dim is sharded over (pass only
    axes whose product divides the batch — callers resolve divisibility)."""
    tok = _CTX.set((mesh, tuple(batch_axes)) if batch_axes else None)
    try:
        yield
    finally:
        _CTX.reset(tok)


def constrain_expert(x: jax.Array, axis: int = 1) -> jax.Array:
    """Pin MoE dispatch buffers (B, E, C, D) to batch-over-data AND
    expert-over-model. Activations are replicated over "model" in TP, so
    every (data i, model j) device can build its (B_i rows x E_j experts)
    tile of the buffer LOCALLY — the dispatch scatter needs no collective
    at all, and the expert einsum consumes E-over-model weights in place.
    (Leaving B unconstrained let GSPMD replicate it and emit 5+ TB of
    scatter all-reduces; E-over-data all_to_all was also tried and beaten
    by this layout — EXPERIMENTS.md §Perf iterations 4a/4b.)"""
    ctx = _CTX.get()
    if ctx is None:
        return x
    mesh, batch_axes = ctx
    if "model" not in mesh.axis_names or x.shape[axis] % mesh.shape["model"]:
        return x
    size = 1
    for a in batch_axes:
        size *= mesh.shape[a]
    spec = [None] * x.ndim
    if x.shape[0] % max(size, 1) == 0 and size > 1:
        spec[0] = batch_axes if len(batch_axes) > 1 else batch_axes[0]
    spec[axis] = "model"
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, P(*spec)))


def constrain(x: jax.Array) -> jax.Array:
    """Pin dim 0 (batch) to the data axes; other dims unconstrained."""
    ctx = _CTX.get()
    if ctx is None or x.ndim == 0:
        return x
    mesh, axes = ctx
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    if x.shape[0] % size != 0:
        return x
    first = axes if len(axes) > 1 else axes[0]
    spec = P(first, *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, spec))
