"""Parameter-sharding rules: param path -> PartitionSpec on the production
mesh (DESIGN.md §4).

Placeholders in the rule table resolve per profile:
  * "model" — tensor/expert parallel axis.
  * "fsdp"  — parameter sharding over the within-pod data axis (ZeRO-style);
              resolves to "data" in the ``fsdp_tp`` profile and to ``None``
              in plain ``tp``.

Every resolved axis is checked for divisibility against the actual dim size;
non-divisible axes drop to ``None`` (replicated) rather than erroring — the
fallback is visible in the dry-run memory analysis and is hillclimb fodder,
never a crash (e.g. whisper's 51865 vocab or 28-head attention vs model=16).
"""
from __future__ import annotations

import re
from typing import Any

import jax
from jax.sharding import Mesh, PartitionSpec as P

# (path regex, spec template) — first match wins; template entries align with
# trailing dims when the leaf has a leading layer-stack axis.
RULES: list[tuple[str, tuple]] = [
    (r"embed/embedding$",                      ("model", "fsdp")),
    (r"embed/lm_head$",                        ("fsdp", "model")),
    (r"dec_pos$",                              (None, None)),
    # attention projections (incl. rglru's attn blocks under mix/)
    (r"(attn|mix)/w[qkv]$",                    (None, "fsdp", "model")),
    (r"(attn|mix)/wo$",                        (None, "model", "fsdp")),
    (r"(attn|mix)/b[qkv]$",                    (None, "model")),
    (r"(q_norm|k_norm)$",                      (None, None)),
    # dense mlp
    (r"mlp/w[ig]$",                            (None, "fsdp", "model")),
    (r"mlp/wo$",                               (None, "model", "fsdp")),
    # moe (L,E,D,F): experts over "model" (EP), d_model over fsdp — the
    # 235B expert weights need 256-way sharding to fit HBM. The companion
    # activation constraint (B over data × E over model on the dispatch
    # buffer, models/moe.py) is what makes this fast: without it GSPMD
    # replicated the buffer batch dim and emitted 5+ TB of scatter
    # all-reduces (EXPERIMENTS.md §Perf iterations 4a/4b).
    (r"moe/router$",                           (None, "fsdp", None)),
    (r"moe/w[ig]$",                            (None, "model", "fsdp", None)),
    (r"moe/wo$",                               (None, "model", None, "fsdp")),
    # rglru recurrent mix
    (r"mix/w_(in|gate)$",                      (None, "fsdp", "model")),
    (r"mix/w_out$",                            (None, "model", "fsdp")),
    (r"mix/conv_w$",                           (None, None, "model")),
    (r"mix/(conv_b|lru_lambda|b_a|b_x)$",      (None, "model")),
    (r"mix/w_[ax]$",                           (None, "fsdp", "model")),
    # rwkv time mix
    (r"tm/w[rkvg]$",                           (None, "fsdp", "model")),
    (r"tm/wo$",                                (None, "model", "fsdp")),
    (r"tm/lora_a$",                            (None, "fsdp", None)),
    (r"tm/lora_b$",                            (None, None, None, "fsdp")),
    (r"tm/decay_a$",                           (None, "fsdp", None)),
    (r"tm/decay_b$",                           (None, None, "fsdp")),
    (r"tm/(mu_x|w0|u|ln_scale)$",              (None, "fsdp")),
    (r"tm/mu$",                                (None, None, "fsdp")),
    # rwkv channel mix
    (r"cm/w[kr]$",                             (None, "fsdp", "model")),
    (r"cm/wv$",                                (None, "model", "fsdp")),
    (r"cm/mu_[kr]$",                           (None, "fsdp")),
]


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name]


def _resolve(template: tuple, shape: tuple, mesh: Mesh, profile: str) -> P:
    """Align the template to the TRAILING dims of ``shape`` — leading dims
    (layer stacks of any depth) stay unsharded; a too-long template loses its
    leading entries (handles stacked vs unstacked leaves uniformly)."""
    tpl = tuple(template)
    if len(tpl) > len(shape):
        tpl = tpl[len(tpl) - len(shape):]
    if len(tpl) < len(shape):
        tpl = (None,) * (len(shape) - len(tpl)) + tpl
    out = []
    for dim, want in zip(shape, tpl):
        axis = None
        if want == "model":
            axis = "model"
        elif want == "fsdp" and profile == "fsdp_tp":
            axis = "data"
        if axis is not None and dim % _axis_size(mesh, axis) != 0:
            axis = None                      # divisibility fallback
        out.append(axis)
    return P(*out)


def param_specs(params_shape: Any, mesh: Mesh, profile: str = "fsdp_tp") -> Any:
    """Pytree of PartitionSpec matching ``params_shape`` (arrays or
    ShapeDtypeStructs)."""
    flat = jax.tree_util.tree_flatten_with_path(params_shape)[0]

    def spec_for(path, leaf):
        pstr = "/".join(str(getattr(k, "key", k)) for k in path)
        for rx, tpl in RULES:
            if re.search(rx, pstr):
                return _resolve(tpl, leaf.shape, mesh, profile)
        return P(*([None] * len(leaf.shape)))

    treedef = jax.tree_util.tree_structure(params_shape)
    specs = [spec_for(p, l) for p, l in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


def batch_specs(batch_shape: Any, mesh: Mesh) -> Any:
    """Shard the leading (batch) dim of every input over all data-like axes."""
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))

    def spec_for(leaf):
        if not leaf.shape:
            return P()
        b = leaf.shape[0]
        axes: tuple = dp
        # drop axes until divisible (e.g. batch 1 for long_500k)
        while axes and b % _prod(mesh, axes) != 0:
            axes = axes[1:]
        first = axes if len(axes) > 1 else (axes[0] if axes else None)
        return P(first, *([None] * (len(leaf.shape) - 1)))

    return jax.tree_util.tree_map(spec_for, batch_shape)


def cache_specs(cache_shape: Any, mesh: Mesh) -> Any:
    """KV caches / recurrent state: (L, B, ...) -> batch dim sharded over
    data axes, head-like dims over model when divisible."""
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))

    model = _axis_size(mesh, "model")

    def spec_for(leaf):
        if leaf.ndim <= 1:
            return P(*([None] * leaf.ndim))
        axes: tuple = dp
        b = leaf.shape[1] if leaf.ndim >= 2 else 0
        while axes and (b == 0 or b % _prod(mesh, axes) != 0):
            axes = axes[1:]
        first = axes if len(axes) > 1 else (axes[0] if axes else None)
        spec = [None, first] + [None] * (leaf.ndim - 2)
        if leaf.ndim == 5 and leaf.shape[3] == leaf.shape[4] \
                and leaf.shape[2] % model == 0:
            # rwkv matrix state (L,B,H,hd,hd): heads over model
            spec[2] = "model"
        elif leaf.ndim == 5:
            # KV cache (L,B,S,KV,hd): prefer kv-head sharding; fall back to
            # SEQUENCE sharding (flash-decode style). Replicating a 32k
            # cache over the model axis costs 16x memory + cache-sized
            # collectives (measured 320 GiB/device on qwen1.5-32b);
            # hd-sharding was tried and REFUTED (310 GiB of cache
            # all-gathers around the dynamic write / attention) —
            # EXPERIMENTS.md §Perf iterations 1a/1b.
            if leaf.shape[3] % model == 0:
                spec[3] = "model"
            elif leaf.shape[2] % model == 0:
                spec[2] = "model"
            elif leaf.shape[4] % model == 0:
                spec[4] = "model"
        elif leaf.ndim == 4 and leaf.shape[2] >= 1024 \
                and leaf.shape[2] % model == 0:
            # KV-quantization scale cache (L,B,S,KV): follow the seq shard
            spec[2] = "model"
        elif leaf.ndim in (3, 4) and leaf.shape[-1] % model == 0:
            # recurrent channel states (G,B,W) / conv states (G,B,cw-1,W):
            # channels over model (RG-LRU is elementwise -> no comm)
            spec[-1] = "model"
        return P(*spec)

    return jax.tree_util.tree_map(spec_for, cache_shape)


def _prod(mesh: Mesh, axes: tuple) -> int:
    n = 1
    for a in axes:
        n *= _axis_size(mesh, a)
    return n
