"""qwen2-7b [dense]: 28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064
— GQA, QKV bias [arXiv:2407.10671; hf]."""
from repro.models.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-7b", family="dense",
    n_layers=28, d_model=3584, n_heads=28, n_kv=4, head_dim=128,
    d_ff=18944, vocab=152064, qkv_bias=True, norm="rmsnorm",
    rope_theta=1_000_000.0,
)


def smoke_config():
    return CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv=2,
                          head_dim=16, d_ff=128, vocab=256)
