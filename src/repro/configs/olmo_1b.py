"""olmo-1b [dense]: 16L d_model=2048 16H (kv=16) d_ff=8192 vocab=50304
— non-parametric LayerNorm, no biases, tied embeddings [arXiv:2402.00838]."""
from repro.models.base import ModelConfig

CONFIG = ModelConfig(
    name="olmo-1b", family="dense",
    n_layers=16, d_model=2048, n_heads=16, n_kv=16, head_dim=128,
    d_ff=8192, vocab=50304, qkv_bias=False, norm="layernorm_nonparam",
    rope_theta=10_000.0, tie_embeddings=True,
)


def smoke_config():
    return CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv=4,
                          head_dim=16, d_ff=128, vocab=256)
