"""qwen2.5-3b [dense]: 36L d_model=2048 16H (GQA kv=2) d_ff=11008
vocab=151936 — GQA, QKV bias [hf:Qwen/Qwen2.5 family; hf]."""
from repro.models.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-3b", family="dense",
    n_layers=36, d_model=2048, n_heads=16, n_kv=2, head_dim=128,
    d_ff=11008, vocab=151936, qkv_bias=True, norm="rmsnorm",
    rope_theta=1_000_000.0,
)


def smoke_config():
    return CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv=2,
                          head_dim=16, d_ff=128, vocab=256)
