"""pixtral-12b [vlm]: 40L d_model=5120 32H (GQA kv=8) d_ff=14336
vocab=131072 — mistral-nemo decoder backbone; the pixtral-ViT patch frontend
is a STUB (input_specs supplies precomputed patch/token embeddings)
[hf:mistralai/Pixtral-12B-2409]."""
from repro.models.base import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b", family="dense",
    n_layers=40, d_model=5120, n_heads=32, n_kv=8, head_dim=128,
    d_ff=14336, vocab=131072, qkv_bias=False, norm="rmsnorm",
    rope_theta=1_000_000.0, input_mode="embeds",
)


def smoke_config():
    return CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv=2,
                          head_dim=16, d_ff=128, vocab=256)
