"""Assigned-architecture registry: ``--arch <id>`` -> ModelConfig.

Each module defines ``CONFIG`` (the exact published configuration) and
``smoke_config()`` (a reduced same-family config for CPU smoke tests).
"""
from __future__ import annotations

import importlib

ARCHS = [
    "qwen2-7b",
    "qwen1.5-32b",
    "olmo-1b",
    "qwen2.5-3b",
    "qwen3-moe-235b-a22b",
    "qwen3-moe-30b-a3b",
    "recurrentgemma-9b",
    "pixtral-12b",
    "rwkv6-1.6b",
    "whisper-medium",
]


def _mod(arch: str):
    return importlib.import_module(f"repro.configs.{arch.replace('-', '_').replace('.', '_')}")


def get_config(arch: str):
    return _mod(arch).CONFIG


def get_smoke_config(arch: str):
    return _mod(arch).smoke_config()
