"""whisper-medium [audio]: 24L (enc) + 24L (dec) d_model=1024 16H (kv=16)
d_ff=4096 vocab=51865 — enc-dec; conv/mel frontend is a STUB (input_specs
supplies precomputed frame embeddings) [arXiv:2212.04356]."""
from repro.models.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium", family="whisper",
    n_layers=24, enc_layers=24, d_model=1024, n_heads=16, n_kv=16,
    head_dim=64, d_ff=4096, vocab=51865, qkv_bias=True, norm="layernorm",
    rope_theta=0.0, input_mode="encdec", dec_len=448,
)


def smoke_config():
    return CONFIG.replace(n_layers=2, enc_layers=2, d_model=64, n_heads=4,
                          n_kv=4, head_dim=16, d_ff=128, vocab=256,
                          dec_len=16)
