"""qwen3-moe-30b-a3b [moe]: 48L d_model=2048 32H (GQA kv=4) expert d_ff=768
vocab=151936, MoE 128 experts top-8, qk-norm [hf:Qwen/Qwen3-30B-A3B; hf]."""
from repro.models.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv=4, head_dim=128,
    d_ff=768, vocab=151936, qkv_bias=False, qk_norm=True, norm="rmsnorm",
    rope_theta=1_000_000.0, n_experts=128, top_k=8,
)


def smoke_config():
    return CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv=2,
                          head_dim=16, d_ff=32, vocab=256, n_experts=8,
                          top_k=2)
