"""recurrentgemma-9b [hybrid]: 38L d_model=4096 16H (GQA kv=1, local attn)
d_ff=12288 vocab=256000 — RG-LRU + local attention, 1 attn per 3 blocks,
window 2048 [arXiv:2402.19427]."""
from repro.models.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b", family="rglru",
    n_layers=38, d_model=4096, n_heads=16, n_kv=1, head_dim=256,
    d_ff=12288, vocab=256000, norm="rmsnorm", rope_theta=10_000.0,
    attn_every=3, window=2048, lru_width=4096, conv_width=4,
    tie_embeddings=True,
)


def smoke_config():
    return CONFIG.replace(n_layers=8, d_model=64, n_heads=4, n_kv=1,
                          head_dim=16, d_ff=128, vocab=256, window=16,
                          lru_width=64)
