"""qwen1.5-32b [dense]: 64L d_model=5120 40H (kv=40, i.e. MHA) d_ff=27392
vocab=152064 — QKV bias [hf:Qwen/Qwen1.5 family; hf]."""
from repro.models.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-32b", family="dense",
    n_layers=64, d_model=5120, n_heads=40, n_kv=40, head_dim=128,
    d_ff=27392, vocab=152064, qkv_bias=True, norm="rmsnorm",
    rope_theta=1_000_000.0,
    # MHA (kv=40) at 32k x batch 128 is a 5.5 TB cache — int8 KV
    # quantization halves it to fit v5e HBM (EXPERIMENTS.md §Perf iter 1c)
    kv_quant=True,
)


def smoke_config():
    return CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv=4,
                          head_dim=16, d_ff=160, vocab=256)
