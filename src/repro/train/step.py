"""Train-step factory: loss -> grads -> AdamW, with optional microbatch
gradient accumulation and optional cross-pod gradient compression.

The returned function is pjit-ready: callers pass in/out shardings from
sharding/rules.py. ``unroll_layers=True`` unrolls the layer scan so the
compiled HLO carries per-layer cost explicitly (required for faithful
cost_analysis in the dry-run — XLA counts a while body once; see DESIGN.md §7).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models import get_family
from repro.models.base import ModelConfig
from repro.optim import adamw


def _with_unroll(fn: Callable, unroll: bool):
    """Patch lax.scan's unroll behaviour for dry-run lowering."""
    if not unroll:
        return fn
    orig = jax.lax.scan

    def scan_unrolled(f, init, xs=None, length=None, **kw):
        kw.pop("unroll", None)
        n = length
        if n is None and xs is not None:
            n = jax.tree_util.tree_leaves(xs)[0].shape[0]
        return orig(f, init, xs, length=length, unroll=n or 1, **kw)

    def wrapped(*a, **k):
        jax.lax.scan = scan_unrolled
        try:
            return fn(*a, **k)
        finally:
            jax.lax.scan = orig
    return wrapped


def make_loss_fn(cfg: ModelConfig):
    fam = get_family(cfg)
    return lambda params, batch: fam.loss_fn(params, batch, cfg)


def make_train_step(cfg: ModelConfig, opt_cfg: adamw.AdamWConfig,
                    n_microbatches: int = 1, unroll_layers: bool = False,
                    grad_transform: Callable[[Any], Any] | None = None):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    ``n_microbatches`` > 1 splits the batch on dim 0 and accumulates grads in
    f32 (sequential scan — the standard memory/throughput trade).
    ``grad_transform`` hooks gradient compression (optim/compression.py).
    """
    loss_fn = _with_unroll(make_loss_fn(cfg), unroll_layers)

    def train_step(params, opt_state, batch):
        if n_microbatches > 1:
            def split(x):
                b = x.shape[0]
                return x.reshape(n_microbatches, b // n_microbatches, *x.shape[1:])
            micro = jax.tree_util.tree_map(split, batch)

            def acc_body(carry, mb):
                loss_acc, grad_acc = carry
                loss, grads = jax.value_and_grad(loss_fn)(params, mb)
                grad_acc = jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(jnp.float32), grad_acc, grads)
                return (loss_acc + loss, grad_acc), None

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(acc_body, (0.0, zeros), micro)
            loss = loss / n_microbatches
            grads = jax.tree_util.tree_map(lambda g: g / n_microbatches, grads)
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        if grad_transform is not None:
            grads = grad_transform(grads)
        new_params, new_opt, metrics = adamw.update(grads, opt_state, params,
                                                    opt_cfg)
        metrics["loss"] = loss
        return new_params, new_opt, metrics

    return train_step
