"""Serving driver: model + engine + CE-backed semantic planner behind one
CLI — the deployment shape of the paper's technique (DESIGN.md §2).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b --scale smoke \
      --requests 8 --corpus 4000

Loads (or initializes) weights, builds the Dynamic Prober index over the
document-embedding corpus, then serves a stream of semantic operators:
estimate -> plan -> batched prefill/decode. On a pod the same driver lowers
full configs (proven by launch/dryrun.py); here it runs reduced configs for
real.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core.config import ProberConfig
from repro.models import get_family
from repro.serve.engine import Request, ServeEngine
from repro.serve.semantic import SemanticPlanner


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=configs.ARCHS, default="qwen2-7b")
    ap.add_argument("--scale", choices=["smoke", "full"], default="smoke")
    ap.add_argument("--corpus", type=int, default=4000)
    ap.add_argument("--emb-dim", type=int, default=64)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--max-calls", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--shards", type=int, default=0,
                    help="shard the estimator corpus over this many devices "
                         "(0 = single-device; needs that many jax devices, "
                         "e.g. via XLA_FLAGS=--xla_force_host_platform_"
                         "device_count=N)")
    ap.add_argument("--stopping", choices=["local", "sync"], default="local",
                    help="distributed stopping mode (DESIGN.md §4); only "
                         "meaningful with --shards > 1")
    args = ap.parse_args(argv)

    key = jax.random.PRNGKey(args.seed)
    cfg = (configs.get_smoke_config(args.arch) if args.scale == "smoke"
           else configs.get_config(args.arch))
    assert cfg.family == "dense", "the engine drives dense-family models"
    fam = get_family(cfg)
    params = fam.init(key, cfg)
    engine = ServeEngine(cfg, params, batch_slots=args.slots,
                         max_len=args.max_len)

    corpus = jax.random.normal(key, (args.corpus, args.emb_dim))
    pcfg = ProberConfig(n_tables=2, n_funcs=8, ring_budget=1024,
                        central_budget=1024, chunk=128)
    mesh = None
    if args.shards > 1:
        from repro import compat
        assert args.corpus % args.shards == 0, \
            f"--shards {args.shards} must divide --corpus {args.corpus}"
        assert len(jax.devices()) >= args.shards, \
            f"--shards {args.shards} needs that many jax devices " \
            f"(have {len(jax.devices())}; set XLA_FLAGS=" \
            f"--xla_force_host_platform_device_count={args.shards})"
        mesh = compat.make_mesh((args.shards,), ("data",),
                                devices=jax.devices()[:args.shards])
    planner = SemanticPlanner(corpus, pcfg, key, max_calls=args.max_calls,
                              slot_budget=args.slots, mesh=mesh,
                              mode=args.stopping)
    where = f"{args.shards}-shard/{args.stopping}" if mesh else "1-device"
    print(f"serving {cfg.name} ({args.scale}) | corpus={args.corpus} docs "
          f"| estimator {where}")

    rng = np.random.default_rng(args.seed)
    served = refused = 0
    t0 = time.time()
    for rid in range(args.requests):
        q = corpus[int(rng.integers(0, args.corpus))]
        d2 = jnp.sort(jnp.sum((corpus - q[None]) ** 2, axis=-1))
        target = int(rng.choice([2, 8, 32, args.max_calls * 4]))
        tau = float(jnp.sqrt(d2[min(target, args.corpus - 1)]))
        plan = planner.plan(q, tau)
        if plan.action != "execute":
            refused += 1
            print(f"req {rid}: est={plan.est_matches:8.1f} -> {plan.action} "
                  f"({plan.reason})")
            continue
        d2q = jnp.sum((corpus - q[None]) ** 2, axis=-1)
        matches = np.asarray(jnp.argsort(d2q)[: max(plan.llm_calls, 1)])
        for doc in matches:
            engine.submit(Request(rid=int(doc),
                                  prompt=rng.integers(2, cfg.vocab, size=8),
                                  max_new=4))
        done = engine.run()
        served += len(done)
        print(f"req {rid}: est={plan.est_matches:8.1f} -> {len(done)} LLM "
              f"calls ({plan.n_batches} batches x {plan.batch_slots} slots)")
    dt = time.time() - t0
    print(f"\n{served} LLM calls served, {refused} operators refused "
          f"by the planner, {dt:.1f}s total")
    return served, refused


if __name__ == "__main__":
    main()
