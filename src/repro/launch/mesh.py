"""Production mesh factory.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — required because the dry-run must set
``xla_force_host_platform_device_count`` before first jax init.
"""
from __future__ import annotations

import jax

from repro import compat


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_host_mesh(model: int = 1) -> jax.sharding.Mesh:
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    assert n % model == 0
    return compat.make_mesh((n // model, model), ("data", "model"))
