"""Training driver: mesh + sharding rules + AdamW + fault-tolerant loop +
checkpointing + straggler telemetry, end to end.

On this CPU container it trains reduced configs for real (examples/
train_tiny_lm.py drives it); on a pod the same driver lowers the full
configs (the dry-run proves those compile).

  PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --scale smoke \
      --steps 60 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.ckpt.checkpoint import CheckpointManager
from repro.data.tokens import TokenPipeline
from repro.ft.failures import FaultTolerantLoop
from repro.ft.straggler import StragglerDetector
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import get_family
from repro.optim import adamw
from repro.sharding import rules
from repro.sharding.act import activation_sharding
from repro.train.step import make_train_step
from jax.sharding import NamedSharding, PartitionSpec as P


def build_trainer(cfg, mesh, opt_cfg, profile="fsdp_tp", microbatches=1):
    fam = get_family(cfg)
    params = fam.init(jax.random.PRNGKey(0), cfg)
    opt_state = adamw.init(params)
    pspecs = jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        rules.param_specs(params, mesh, profile),
        is_leaf=lambda x: isinstance(x, P))
    ospecs = {"m": pspecs, "v": pspecs, "step": NamedSharding(mesh, P())}
    params = jax.device_put(params, pspecs)
    opt_state = jax.device_put(opt_state, ospecs)
    step_fn = make_train_step(cfg, opt_cfg, n_microbatches=microbatches)
    rep = NamedSharding(mesh, P())

    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    with activation_sharding(mesh, dp):
        jitted = jax.jit(step_fn,
                         out_shardings=(pspecs, ospecs,
                                        {"lr": rep, "grad_norm": rep,
                                         "loss": rep}),
                         donate_argnums=(0, 1))
    return params, opt_state, jitted


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=configs.ARCHS, default="olmo-1b")
    ap.add_argument("--scale", choices=["smoke", "full"], default="smoke")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--mesh", choices=["host", "prod", "prod-multi"],
                    default="host")
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = (configs.get_smoke_config(args.arch) if args.scale == "smoke"
           else configs.get_config(args.arch))
    if args.mesh == "host":
        mesh = make_host_mesh(model=args.model_parallel)
    else:
        mesh = make_production_mesh(multi_pod=(args.mesh == "prod-multi"))
    opt_cfg = adamw.AdamWConfig(lr=args.lr, warmup_steps=10,
                                total_steps=args.steps)
    params, opt_state, jitted = build_trainer(cfg, mesh, opt_cfg,
                                              microbatches=args.microbatches)
    pipeline = TokenPipeline(vocab=cfg.vocab, batch=args.batch, seq=args.seq)
    ckpt = CheckpointManager(args.ckpt_dir, keep=3)
    detector = StragglerDetector()

    state = {"params": params, "opt": opt_state}
    t_last = [time.time()]

    def step_fn(state, batch):
        p, o, metrics = jitted(state["params"], state["opt"], batch)
        metrics["loss"].block_until_ready()
        now = time.time()
        detector.record(0, now - t_last[0])
        t_last[0] = now
        return {"params": p, "opt": o}, metrics

    loop = FaultTolerantLoop(step_fn, ckpt, pipeline,
                             save_every=args.save_every)
    state, log = loop.run(state, args.steps)
    for rec in log[:: max(args.log_every, 1)] + log[-1:]:
        print(f"step {rec['step']:5d} loss {rec['loss']:.4f} "
              f"lr {rec['lr']:.2e} gnorm {rec['grad_norm']:.3f}")
    if detector.stragglers():
        print("stragglers detected:", detector.stragglers())
    first, last = log[0]["loss"], log[-1]["loss"]
    print(f"loss {first:.4f} -> {last:.4f} "
          f"({'improved' if last < first else 'NOT improved'})")
    return log


if __name__ == "__main__":
    main()
