import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Dry-run of the PAPER'S technique itself at production scale: the
distributed Dynamic Prober (shard_map + psum) over a 1.05-billion-point
corpus sharded across the single-pod mesh (256 chips x 4.1M points each),
answering a 64-query batch.

Proves the estimator's distribution config lowers+compiles on the production
mesh and reports its roofline terms. The ring/chunk while-loops have
data-dependent early stops, so collective/FLOP totals are the worst-case
bound (every ring probed to budget).

  PYTHONPATH=src python -m repro.launch.dryrun_ce
"""
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.core import distributed as D, estimator as E, lsh
from repro.core.config import ProberConfig
from repro.launch.mesh import make_production_mesh
from repro.utils import hlo as hlo_util
from repro.utils import roofline


def main(n_per_shard: int = 4_096_000, dim: int = 128, n_queries: int = 64,
         out_dir: str = "results/dryrun"):
    cfg = ProberConfig(n_tables=2, n_funcs=12, ring_budget=8192,
                       central_budget=8192, chunk=512, max_visit=32768)
    mesh = make_production_mesh()
    shards = mesh.size
    n_global = n_per_shard * shards
    print(f"corpus: {n_global/1e9:.2f}B x {dim} over {shards} chips")

    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    x_loc = jax.ShapeDtypeStruct((n_per_shard, dim), jnp.float32)
    params = jax.eval_shape(lambda k: lsh.init_params(k, dim, cfg), key)

    # abstract per-shard state with a leading shard axis (the layout
    # distributed.build_sharded produces)
    local_state = jax.eval_shape(
        lambda x, k, p: E.build(x, cfg, k, params=p), x_loc, key, params)
    state_abs = jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct((shards,) + s.shape, s.dtype),
        local_state)
    qs = jax.ShapeDtypeStruct((n_queries, dim), jnp.float32)
    taus = jax.ShapeDtypeStruct((n_queries,), jnp.float32)

    def fn(state, qs, taus, key):
        # CE has no tensor-parallel dim: partition the corpus over BOTH
        # mesh axes (256-way)
        return D.estimate_sharded(state, qs, taus, cfg, key, mesh,
                                  data_axes=("data", "model"))

    t0 = time.time()
    lowered = jax.jit(fn).lower(state_abs, qs, taus, key)
    compiled = lowered.compile()
    secs = time.time() - t0
    ca = compiled.cost_analysis() or {}
    ma = compiled.memory_analysis()
    coll = hlo_util.collective_bytes(compiled.as_text())
    # "model flops": exact brute force over the full corpus for the batch
    brute = 2.0 * n_global * dim * n_queries
    rf = roofline.make(float(ca.get("flops", 0.0)),
                       float(ca.get("bytes accessed", 0.0)),
                       float(coll["total"]), shards, brute)
    rec = {
        "arch": "dynamic-prober-ce", "shape": f"{n_global}pts_{n_queries}q",
        "mesh": "single", "chips": shards, "compile_s": round(secs, 1),
        "memory": {k: int(getattr(ma, k, 0)) for k in
                   ("argument_size_in_bytes", "peak_memory_in_bytes",
                    "temp_size_in_bytes")},
        "collectives": coll,
        "roofline": rf.to_dict(),
        "note": "worst-case bound (data-dependent early stop not modeled); "
                "model_flops = exact brute-force cost the estimator replaces",
    }
    out = Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    (out / "ce_estimator__single.json").write_text(json.dumps(rec, indent=1))
    r = rec["roofline"]
    print(f"OK CE dry-run: compile={secs:.0f}s "
          f"t=({r['t_compute_s']:.2e},{r['t_memory_s']:.2e},"
          f"{r['t_collective_s']:.2e})s dominant={r['dominant']} "
          f"peak={rec['memory']['peak_memory_in_bytes']/2**30:.2f}GiB "
          f"args={rec['memory']['argument_size_in_bytes']/2**30:.2f}GiB")
    print(f"brute-force equivalent would cost "
          f"{brute/(shards*roofline.PEAK_FLOPS):.2e}s of pure compute")


if __name__ == "__main__":
    main()
