"""input_specs(): ShapeDtypeStruct stand-ins for every (arch × shape) cell —
weak-type-correct, shardable, no device allocation.

Shape grid (assignment):
    train_4k     seq=4096   global_batch=256   (train_step)
    prefill_32k  seq=32768  global_batch=32    (prefill)
    decode_32k   seq=32768  global_batch=128   (decode: 1 token, KV cache=seq)
    long_500k    seq=524288 global_batch=1     (decode; sub-quadratic archs only)

Modality frontends are stubs per the assignment: pixtral gets precomputed
patch/token embeddings (B, S, D); whisper gets precomputed frame embeddings.
Whisper train/decode use dec_len decoder tokens and a 1500-frame (native)
cross-attention span for decode cells.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import get_family
from repro.models.base import ModelConfig

SHAPES = {
    "train_4k":    dict(seq=4096, batch=256, kind="train"),
    "prefill_32k": dict(seq=32768, batch=32, kind="prefill"),
    "decode_32k":  dict(seq=32768, batch=128, kind="decode"),
    "long_500k":   dict(seq=524288, batch=1, kind="decode"),
}

SUBQUADRATIC = {"rglru", "rwkv6"}
_WHISPER_NATIVE_ENC = 1504   # ~30 s of audio frames, padded to a lane multiple


def cell_supported(cfg: ModelConfig, shape: str) -> tuple[bool, str]:
    if shape == "long_500k" and cfg.family not in SUBQUADRATIC:
        return False, ("full-attention architecture: a 524288-token decode "
                       "needs sub-quadratic attention (skip noted in DESIGN.md §5)")
    return True, ""


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs_for(cfg: ModelConfig, shape: str):
    """ShapeDtypeStructs for the *data* inputs of the cell."""
    info = SHAPES[shape]
    s, b, kind = info["seq"], info["batch"], info["kind"]
    tok = jnp.int32
    act = jnp.bfloat16
    if kind == "train":
        if cfg.family == "whisper":
            return {"frames": _sds((b, s, cfg.d_model), act),
                    "tokens": _sds((b, cfg.dec_len), tok),
                    "labels": _sds((b, cfg.dec_len), tok)}
        if cfg.input_mode == "embeds":
            return {"embeds": _sds((b, s, cfg.d_model), act),
                    "labels": _sds((b, s), tok)}
        return {"tokens": _sds((b, s), tok), "labels": _sds((b, s), tok)}
    if kind == "prefill":
        if cfg.family == "whisper":
            return {"frames": _sds((b, s, cfg.d_model), act)}
        if cfg.input_mode == "embeds":
            return {"embeds": _sds((b, s, cfg.d_model), act)}
        return {"tokens": _sds((b, s), tok)}
    # decode: tokens only; the cache comes from cache_specs_for
    return {"tokens": _sds((b,), tok)}


def cache_specs_for(cfg: ModelConfig, shape: str):
    """Abstract KV-cache / recurrent-state for decode cells (no allocation)."""
    info = SHAPES[shape]
    s, b = info["seq"], info["batch"]
    fam = get_family(cfg)
    kw = {}
    if cfg.family == "whisper":
        kw["enc_len"] = _WHISPER_NATIVE_ENC
    return jax.eval_shape(lambda: fam.init_cache(cfg, b, s, **kw))


def param_specs_for(cfg: ModelConfig):
    """Abstract params via eval_shape — zero allocation at any size."""
    fam = get_family(cfg)
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    return jax.eval_shape(lambda k: fam.init(k, cfg), key)
