import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape) on the production
meshes and extract memory / cost / collective analysis (EXPERIMENTS.md
§Dry-run, §Roofline).

The two lines above MUST precede any jax import — jax locks the device count
on first init. Everything below is ShapeDtypeStruct-abstract: no tensor of
any full-size architecture is ever allocated.

Usage:
  python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--mesh both] [--out-dir results/dryrun]

``--all`` fans out one subprocess per cell (isolates XLA compile state and
lets a failed cell fail alone); each cell writes a JSON record.
"""
import argparse
import dataclasses
import json
import subprocess
import sys
import time
from pathlib import Path

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.launch import specs as S
from repro.launch.mesh import make_production_mesh
from repro.models.base import ModelConfig
from repro.optim import adamw
from repro.serve.step import make_decode_step, make_prefill_step
from repro.sharding import rules
from repro.sharding.act import activation_sharding
from repro.train.step import make_train_step
from repro.utils import hlo as hlo_util
from repro.utils import roofline


def _named(mesh, tree_of_specs):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), tree_of_specs,
        is_leaf=lambda x: isinstance(x, P))


def lower_cell(cfg: ModelConfig, shape: str, mesh, profile: str = "fsdp_tp",
               unroll: bool = True, opt_overrides: dict | None = None):
    """Lower + compile one cell; returns (compiled, lowered, seconds)."""
    info = S.SHAPES[shape]
    kind = info["kind"]
    params_abs = S.param_specs_for(cfg)
    pspecs = _named(mesh, rules.param_specs(params_abs, mesh, profile))
    batch_abs = S.batch_specs_for(cfg, shape)
    bspecs = _named(mesh, rules.batch_specs(batch_abs, mesh))
    # activation constraints: batch over the data-like axes that divide it
    act_axes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    while act_axes and info["batch"] % _prod(mesh, act_axes) != 0:
        act_axes = act_axes[1:]
    t0 = time.time()
    act = activation_sharding(mesh, act_axes)

    if kind == "train":
        opt_abs = jax.eval_shape(adamw.init, params_abs)
        # optimizer m/v mirror the param tree specs; step is replicated
        ospecs = {"m": pspecs, "v": pspecs,
                  "step": NamedSharding(mesh, P())}
        step = make_train_step(cfg, adamw.AdamWConfig(), unroll_layers=unroll,
                               **(opt_overrides or {}))
        rep = NamedSharding(mesh, P())
        jitted = jax.jit(step,
                         in_shardings=(pspecs, ospecs, bspecs),
                         out_shardings=(pspecs, ospecs,
                                        {"lr": rep, "grad_norm": rep, "loss": rep}),
                         donate_argnums=(0, 1))
        with act:
            lowered = jitted.lower(params_abs, opt_abs, batch_abs)
    elif kind == "prefill":
        fn = make_prefill_step(cfg, unroll_layers=unroll)
        dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
        dp_axis = dp if len(dp) > 1 else dp[0]
        vshard = "model" if cfg.vocab % mesh.shape["model"] == 0 else None
        out = NamedSharding(mesh, P(dp_axis, vshard))
        jitted = jax.jit(fn, in_shardings=(pspecs, bspecs), out_shardings=out)
        with act:
            lowered = jitted.lower(params_abs, batch_abs)
    else:  # decode
        # Serving policy (§Perf iteration 3): params in bf16 (production
        # serving precision — halves weight reads/gathers) and, when the
        # model-sharded copy fits HBM alongside the cache, profile "tp"
        # (weights replicated over data -> zero per-step FSDP re-gathers).
        import jax.numpy as jnp
        params_abs = jax.tree_util.tree_map(
            lambda t: jax.ShapeDtypeStruct(
                t.shape, jnp.bfloat16 if t.dtype == jnp.float32 else t.dtype),
            params_abs)
        per_dev_weight_gib = cfg.param_count() * 2 / mesh.shape["model"] / 2**30
        if per_dev_weight_gib <= 4.0:
            profile = "tp"
        pspecs = _named(mesh, rules.param_specs(params_abs, mesh, profile))
        fn = make_decode_step(cfg, unroll_layers=unroll)
        cache_abs = S.cache_specs_for(cfg, shape)
        cspecs = _named(mesh, rules.cache_specs(cache_abs, mesh))
        dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
        b = info["batch"]
        while dp and b % _prod(mesh, dp) != 0:
            dp = dp[1:]
        dp_axis = dp if len(dp) > 1 else (dp[0] if dp else None)
        vshard = "model" if cfg.vocab % mesh.shape["model"] == 0 else None
        out_logits = NamedSharding(mesh, P(dp_axis, vshard))
        jitted = jax.jit(fn,
                         in_shardings=(pspecs, cspecs, bspecs["tokens"]),
                         out_shardings=(out_logits, cspecs),
                         donate_argnums=(1,))
        with act:
            lowered = jitted.lower(params_abs, cache_abs, batch_abs["tokens"])

    compiled = lowered.compile()
    return compiled, lowered, time.time() - t0


def _prod(mesh, axes):
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _delta_correct(cfg: ModelConfig, shape: str, mesh, profile: str) -> dict:
    """Per-layer FLOPs/bytes via the delta method (DESIGN.md §7).

    ``cost_analysis`` counts a ``lax.scan`` body once, so the full-L scan-mode
    compile undercounts per-layer work by ~L×. Recover the true totals by
    compiling the SAME cell at two small layer counts with the scan unrolled:

        per_unit   = (cost(k2) - cost(k1)) / (k2 - k1)      [unit = layer/group]
        corrected  = cost(k1) + per_unit * (units_full - units_k1)

    rglru varies in 3-block groups (tail counted as 2/3 group); whisper
    varies enc+dec together. The rwkv6 inner time-scan stays undercounted
    (<2% of layer FLOPs — the recurrence is elementwise next to the
    projections; DESIGN.md §7).
    """
    if cfg.family == "rglru":
        per = cfg.attn_every or 3
        k1, k2 = per, 2 * per
        mk = lambda k: cfg.replace(n_layers=k)
        u1, u2 = 1.0, 2.0
        units_full = cfg.n_layers / per
    elif cfg.family == "whisper":
        k1, k2 = 1, 2
        mk = lambda k: cfg.replace(n_layers=k, enc_layers=k)
        u1, u2 = 1.0, 2.0
        units_full = float(cfg.n_layers)
    else:
        k1, k2 = 1, 2
        mk = lambda k: cfg.replace(n_layers=k)
        u1, u2 = 1.0, 2.0
        units_full = float(cfg.n_layers)

    costs = []
    for k in (k1, k2):
        comp, _, _ = lower_cell(mk(k), shape, mesh, profile, unroll=True)
        ca = comp.cost_analysis() or {}
        costs.append((float(ca.get("flops", 0.0)),
                      float(ca.get("bytes accessed", 0.0))))
    (f1, b1), (f2, b2) = costs
    per_f = (f2 - f1) / (u2 - u1)
    per_b = (b2 - b1) / (u2 - u1)
    return {"flops": f1 + per_f * (units_full - u1),
            "bytes": b1 + per_b * (units_full - u1),
            "per_unit_flops": per_f, "per_unit_bytes": per_b,
            "raw_small": costs}


def analyze(cfg: ModelConfig, shape: str, compiled, chips: int,
            seconds: float, corrected: dict | None = None) -> dict:
    ca = compiled.cost_analysis() or {}
    ma = compiled.memory_analysis()
    text = compiled.as_text()
    coll = hlo_util.collective_bytes(text)
    info = S.SHAPES[shape]
    mf = roofline.model_flops_for(cfg, info)
    # delta-corrected totals can only be >= the raw (scan-body-once) values;
    # clamp guards tiny-model compile noise producing negative deltas
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", 0.0))
    if corrected:
        flops = max(corrected["flops"], flops)
        byts = max(corrected["bytes"], byts)
    rf = roofline.make(flops, byts, float(coll["total"]), chips, mf)
    mem = {k: int(getattr(ma, k, 0)) for k in
           ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "peak_memory_in_bytes",
            "alias_size_in_bytes")}
    return {
        "arch": cfg.name, "shape": shape, "chips": chips,
        "compile_s": round(seconds, 1),
        "memory": mem,
        "cost_raw": {"flops": float(ca.get("flops", 0.0)),
                     "bytes_accessed": float(ca.get("bytes accessed", 0.0))},
        "cost_corrected": corrected,
        "collectives": coll,
        "roofline": rf.to_dict(),
        "while_trip_counts": hlo_util.while_trip_counts(text)[:16],
    }


def run_cell(arch: str, shape: str, mesh_kind: str, out_dir: Path,
             profile: str, unroll: bool) -> dict:
    cfg = configs.get_config(arch)
    ok, why = S.cell_supported(cfg, shape)
    rec_path = out_dir / f"{arch}__{shape}__{mesh_kind}.json"
    if not ok:
        rec = {"arch": arch, "shape": shape, "mesh": mesh_kind,
               "skipped": why}
        rec_path.write_text(json.dumps(rec, indent=1))
        print(f"SKIP {arch} {shape}: {why}")
        return rec
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = mesh.size
    compiled, lowered, secs = lower_cell(cfg, shape, mesh, profile, unroll)
    corrected = None
    if not unroll:   # scan-mode full compile: apply the delta correction
        corrected = _delta_correct(cfg, shape, mesh, profile)
    rec = analyze(cfg, shape, compiled, chips, secs, corrected)
    rec["mesh"] = mesh_kind
    rec["profile"] = profile
    rec["unrolled"] = unroll
    rec_path.write_text(json.dumps(rec, indent=1))
    r = rec["roofline"]
    print(f"OK {arch} {shape} {mesh_kind}: compile={secs:.0f}s "
          f"dominant={r['dominant']} t=({r['t_compute_s']:.2e},"
          f"{r['t_memory_s']:.2e},{r['t_collective_s']:.2e})s "
          f"useful={r['useful_ratio']:.2f} "
          f"peak_mem={rec['memory']['peak_memory_in_bytes']/2**30:.2f}GiB")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=configs.ARCHS)
    ap.add_argument("--shape", choices=list(S.SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--profile", default="fsdp_tp", choices=["tp", "fsdp_tp"])
    ap.add_argument("--unroll", action="store_true",
                    help="unroll the layer scan in the FULL compile (heavy; "
                         "default uses scan + delta-method correction)")
    ap.add_argument("--out-dir", default="results/dryrun")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--resume", action="store_true",
                    help="skip cells whose JSON already exists")
    args = ap.parse_args()
    out_dir = Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    if args.all:
        failures = []
        for arch in configs.ARCHS:
            for shape in S.SHAPES:
                for mk in meshes:
                    rec = out_dir / f"{arch}__{shape}__{mk}.json"
                    if args.resume and rec.exists():
                        continue
                    cmd = [sys.executable, "-m", "repro.launch.dryrun",
                           "--arch", arch, "--shape", shape, "--mesh", mk,
                           "--profile", args.profile,
                           "--out-dir", str(out_dir)]
                    if args.unroll:
                        cmd.append("--unroll")
                    r = subprocess.run(cmd)
                    if r.returncode != 0:
                        failures.append((arch, shape, mk))
        if failures:
            print("FAILED cells:", failures)
            sys.exit(1)
        print("all cells OK")
        return

    assert args.arch and args.shape, "--arch/--shape or --all required"
    for mk in meshes:
        run_cell(args.arch, args.shape, mk, out_dir, args.profile,
                 args.unroll)


if __name__ == "__main__":
    main()
