"""Blocked squared-L2 distance kernel: ``(N,d) × (Q,d) → (N,Q)``.

The online-estimation hot spot (paper §4.4: "distance computation is the
bottleneck"). Uses the MXU via ``d² = ‖x‖² − 2 x·qᵀ + ‖q‖²`` — one matmul per
(bn, bq) tile plus cheap rank-1 corrections, instead of the VPU-bound
elementwise (x−q)² reduce.

Grid: (N/bn, Q/bq); the contraction dim d stays resident per tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, q_ref, out_ref):
    x = x_ref[...]                     # (bn, d)
    q = q_ref[...]                     # (bq, d)
    xq = jnp.dot(x, q.T, preferred_element_type=jnp.float32)   # (bn, bq) MXU
    x2 = jnp.sum(x * x, axis=-1, keepdims=True)                # (bn, 1)
    q2 = jnp.sum(q * q, axis=-1, keepdims=True).T              # (1, bq)
    out_ref[...] = x2 - 2.0 * xq + q2


@functools.partial(jax.jit, static_argnames=("bn", "bq", "interpret"))
def l2dist(x: jax.Array, q: jax.Array, *, bn: int = 256, bq: int = 128,
           interpret: bool = True) -> jax.Array:
    """x (N, d), q (Q, d) → squared distances (N, Q) float32."""
    n, d = x.shape
    nq = q.shape[0]
    bn = min(bn, n)
    bq = min(bq, nq)
    pad_n = (-n) % bn
    pad_q = (-nq) % bq
    xp = jnp.pad(x, ((0, pad_n), (0, 0)))
    qp = jnp.pad(q, ((0, pad_q), (0, 0)))
    grid = (xp.shape[0] // bn, qp.shape[0] // bq)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bq, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bn, bq), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((xp.shape[0], qp.shape[0]), jnp.float32),
        interpret=interpret,
    )(xp, qp)
    return out[:n, :nq]
