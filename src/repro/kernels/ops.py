"""Jit'd public wrappers over the Pallas kernels.

On a TPU backend the kernels compile natively; on CPU (this container, and
any unit-test environment) they execute via ``interpret=True``, which runs
the kernel body in Python with identical semantics. ``KERNEL_INTERPRET``
flips automatically off on TPU.
"""
from __future__ import annotations

import jax

from repro.kernels import adc as _adc
from repro.kernels import hamming as _hamming
from repro.kernels import l2dist as _l2dist
from repro.kernels import lsh_hash as _lsh_hash

KERNEL_INTERPRET = jax.default_backend() != "tpu"


def lsh_hash(x, a, b, w, **kw):
    kw.setdefault("interpret", KERNEL_INTERPRET)
    return _lsh_hash.lsh_hash(x, a, b, w, **kw)


def l2dist(x, q, **kw):
    kw.setdefault("interpret", KERNEL_INTERPRET)
    return _l2dist.l2dist(x, q, **kw)


def adc(codes, lut, **kw):
    kw.setdefault("interpret", KERNEL_INTERPRET)
    return _adc.adc(codes, lut, **kw)


def adc_batch(codes, luts, **kw):
    kw.setdefault("interpret", KERNEL_INTERPRET)
    return _adc.adc_batch(codes, luts, **kw)


def adc_q8(codes, qlut, **kw):
    kw.setdefault("interpret", KERNEL_INTERPRET)
    return _adc.adc_q8(codes, qlut, **kw)


def adc_batch_q8(codes, qluts, **kw):
    kw.setdefault("interpret", KERNEL_INTERPRET)
    return _adc.adc_batch_q8(codes, qluts, **kw)


def hamming(bucket_codes, qcode, **kw):
    kw.setdefault("interpret", KERNEL_INTERPRET)
    return _hamming.hamming(bucket_codes, qcode, **kw)
