"""PQ-ADC distance kernel (paper Alg. 5): ``dist[n] = Σ_m lut[m, codes[n,m]]``.

TPU adaptation of the LUT gather (DESIGN.md §3): TPUs have no fast random
gather, so the per-subspace lookup becomes a **compare-against-iota one-hot
contraction** executed per subspace inside the kernel — an (bn, Kc) mask times
the LUT row, accumulated over M via ``fori_loop``. The whole LUT
(M×Kc×4B ≤ 32 KiB for M=32, Kc=256) lives in VMEM for the kernel's lifetime;
codes stream through in (bn, M) int32 tiles.

Grid: (N/bn,).

The batched variant :func:`adc_batch` (DESIGN.md §9) serves Q concurrent
queries in a single pass over the codes: all Q per-query LUTs — (Q, M, Kc),
Q×M×Kc×4B, e.g. 2 MiB for Q=64 or 8 MiB for Q=256 at M=32/Kc=256 (size Q
to leave VMEM headroom for the code tiles) — stay resident in VMEM while
each (bn, M) code tile is read ONCE and contracted against every LUT,
emitting a (Q, bn) distance tile per grid step. The one-hot mask is shared
across queries, so the per-subspace work becomes a (bn, Kc) @ (Kc, Q) matmul
that the MXU executes natively; code-tile bandwidth is amortised Q-fold over
the single-query kernel called in a loop. Consumed by the batched
full-scan baseline (``core/baselines.adc_scan_estimate_batch``) — the
non-adaptive counterpart of the prober, benchmarked in
benchmarks/bench_adc.py.

Quantized datapath (DESIGN.md §11): :func:`adc_q8` / :func:`adc_batch_q8`
take the affine uint8 LUTs of ``pq.quantize_lut`` and return raw int32
entry sums ``S[n] = Σ_m qlut[m, codes[n,m]]`` (dequantize as
``offset·M + scale·S``, or compare against ``pq.quantized_threshold``
without ever leaving the integer domain). The VMEM-resident LUT block is
uint8 — 4× smaller than float32 — so 2-4× more queries' LUTs fit beside
the code tiles; the contraction accumulates in int32
(``preferred_element_type``), which is exact (max sum = M·255 « 2^31).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(codes_ref, lut_ref, out_ref):
    codes = codes_ref[...]             # (bn, M) int32
    lut = lut_ref[...]                 # (M, Kc) f32
    bn = codes.shape[0]
    m, kc = lut.shape
    iota = jax.lax.broadcasted_iota(jnp.int32, (bn, kc), 1)

    def body(j, acc):
        onehot = (codes[:, j][:, None] == iota).astype(jnp.float32)  # (bn,Kc)
        return acc + onehot @ lut[j, :]                              # matvec

    acc = jax.lax.fori_loop(0, m, body, jnp.zeros((bn,), jnp.float32))
    out_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("bn", "interpret"))
def adc(codes: jax.Array, lut: jax.Array, *, bn: int = 512,
        interpret: bool = True) -> jax.Array:
    """codes (N, M) int (any width), lut (M, Kc) f32 → squared distances (N,)."""
    n, m = codes.shape
    bn = min(bn, n)
    pad_n = (-n) % bn
    cp = jnp.pad(codes.astype(jnp.int32), ((0, pad_n), (0, 0)))
    grid = (cp.shape[0] // bn,)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, m), lambda i: (i, 0)),
            pl.BlockSpec(lut.shape, lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bn,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((cp.shape[0],), jnp.float32),
        interpret=interpret,
    )(cp, lut)
    return out[:n]


def _batch_kernel(codes_ref, luts_ref, out_ref):
    codes = codes_ref[...]             # (bn, M) int32
    luts = luts_ref[...]               # (Q, M, Kc) f32
    bn = codes.shape[0]
    q, m, kc = luts.shape
    iota = jax.lax.broadcasted_iota(jnp.int32, (bn, kc), 1)

    def body(j, acc):
        onehot = (codes[:, j][:, None] == iota).astype(jnp.float32)  # (bn,Kc)
        return acc + onehot @ luts[:, j, :].T                        # (bn, Q)

    acc = jax.lax.fori_loop(0, m, body, jnp.zeros((bn, q), jnp.float32))
    out_ref[...] = acc.T


@functools.partial(jax.jit, static_argnames=("bn", "interpret"))
def adc_batch(codes: jax.Array, luts: jax.Array, *, bn: int = 512,
              interpret: bool = True) -> jax.Array:
    """codes (N, M) int32, luts (Q, M, Kc) f32 → squared distances (Q, N).

    One scan over the codes serves all Q queries; equivalent to (but much
    cheaper than) stacking ``adc(codes, luts[i])`` for each i.
    """
    n, m = codes.shape
    q = luts.shape[0]
    bn = min(bn, n)
    pad_n = (-n) % bn
    cp = jnp.pad(codes.astype(jnp.int32), ((0, pad_n), (0, 0)))
    grid = (cp.shape[0] // bn,)
    out = pl.pallas_call(
        _batch_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, m), lambda i: (i, 0)),
            pl.BlockSpec(luts.shape, lambda i: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((q, bn), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((q, cp.shape[0]), jnp.float32),
        interpret=interpret,
    )(cp, luts)
    return out[:, :n]


def _kernel_q8(codes_ref, lut_ref, out_ref):
    codes = codes_ref[...]             # (bn, M) int32
    lut = lut_ref[...]                 # (M, Kc) uint8 — 4x less VMEM
    bn = codes.shape[0]
    m, kc = lut.shape
    iota = jax.lax.broadcasted_iota(jnp.int32, (bn, kc), 1)

    def body(j, acc):
        onehot = (codes[:, j][:, None] == iota).astype(jnp.int32)
        return acc + jnp.dot(onehot, lut[j, :].astype(jnp.int32),
                             preferred_element_type=jnp.int32)

    acc = jax.lax.fori_loop(0, m, body, jnp.zeros((bn,), jnp.int32))
    out_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("bn", "interpret"))
def adc_q8(codes: jax.Array, qlut: jax.Array, *, bn: int = 512,
           interpret: bool = True) -> jax.Array:
    """codes (N, M) int, qlut (M, Kc) uint8 → int32 LUT-entry sums (N,).

    Integer counterpart of :func:`adc` for the quantized ADC datapath
    (DESIGN.md §11); the accumulation is exact in int32.
    """
    n, m = codes.shape
    bn = min(bn, n)
    pad_n = (-n) % bn
    cp = jnp.pad(codes.astype(jnp.int32), ((0, pad_n), (0, 0)))
    grid = (cp.shape[0] // bn,)
    out = pl.pallas_call(
        _kernel_q8,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, m), lambda i: (i, 0)),
            pl.BlockSpec(qlut.shape, lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bn,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((cp.shape[0],), jnp.int32),
        interpret=interpret,
    )(cp, qlut)
    return out[:n]


def _batch_kernel_q8(codes_ref, luts_ref, out_ref):
    codes = codes_ref[...]             # (bn, M) int32
    luts = luts_ref[...]               # (Q, M, Kc) uint8 — 4x less VMEM
    bn = codes.shape[0]
    q, m, kc = luts.shape
    iota = jax.lax.broadcasted_iota(jnp.int32, (bn, kc), 1)

    def body(j, acc):
        onehot = (codes[:, j][:, None] == iota).astype(jnp.int32)
        return acc + jnp.dot(onehot, luts[:, j, :].astype(jnp.int32).T,
                             preferred_element_type=jnp.int32)   # (bn, Q)

    acc = jax.lax.fori_loop(0, m, body, jnp.zeros((bn, q), jnp.int32))
    out_ref[...] = acc.T


@functools.partial(jax.jit, static_argnames=("bn", "interpret"))
def adc_batch_q8(codes: jax.Array, qluts: jax.Array, *, bn: int = 512,
                 interpret: bool = True) -> jax.Array:
    """codes (N, M) int32, qluts (Q, M, Kc) uint8 → int32 sums (Q, N).

    Integer counterpart of :func:`adc_batch` (DESIGN.md §11): one pass over
    the codes serves all Q queries with the LUT block resident in VMEM at a
    quarter of the float32 footprint — e.g. Q=256 at M=32/Kc=256 costs
    2 MiB instead of 8 MiB, so 2-4× more queries batch into one scan before
    VMEM pressure forces a split.
    """
    n, m = codes.shape
    q = qluts.shape[0]
    bn = min(bn, n)
    pad_n = (-n) % bn
    cp = jnp.pad(codes.astype(jnp.int32), ((0, pad_n), (0, 0)))
    grid = (cp.shape[0] // bn,)
    out = pl.pallas_call(
        _batch_kernel_q8,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, m), lambda i: (i, 0)),
            pl.BlockSpec(qluts.shape, lambda i: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((q, bn), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((q, cp.shape[0]), jnp.int32),
        interpret=interpret,
    )(cp, qluts)
    return out[:, :n]
