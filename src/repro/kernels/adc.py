"""PQ-ADC distance kernel (paper Alg. 5): ``dist[n] = Σ_m lut[m, codes[n,m]]``.

TPU adaptation of the LUT gather (DESIGN.md §3): TPUs have no fast random
gather, so the per-subspace lookup becomes a **compare-against-iota one-hot
contraction** executed per subspace inside the kernel — an (bn, Kc) mask times
the LUT row, accumulated over M via ``fori_loop``. The whole LUT
(M×Kc×4B ≤ 32 KiB for M=32, Kc=256) lives in VMEM for the kernel's lifetime;
codes stream through in (bn, M) int32 tiles.

Grid: (N/bn,).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(codes_ref, lut_ref, out_ref):
    codes = codes_ref[...]             # (bn, M) int32
    lut = lut_ref[...]                 # (M, Kc) f32
    bn = codes.shape[0]
    m, kc = lut.shape
    iota = jax.lax.broadcasted_iota(jnp.int32, (bn, kc), 1)

    def body(j, acc):
        onehot = (codes[:, j][:, None] == iota).astype(jnp.float32)  # (bn,Kc)
        return acc + onehot @ lut[j, :]                              # matvec

    acc = jax.lax.fori_loop(0, m, body, jnp.zeros((bn,), jnp.float32))
    out_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("bn", "interpret"))
def adc(codes: jax.Array, lut: jax.Array, *, bn: int = 512,
        interpret: bool = True) -> jax.Array:
    """codes (N, M) int32, lut (M, Kc) f32 → squared ADC distances (N,)."""
    n, m = codes.shape
    bn = min(bn, n)
    pad_n = (-n) % bn
    cp = jnp.pad(codes, ((0, pad_n), (0, 0)))
    grid = (cp.shape[0] // bn,)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, m), lambda i: (i, 0)),
            pl.BlockSpec(lut.shape, lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bn,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((cp.shape[0],), jnp.float32),
        interpret=interpret,
    )(cp, lut)
    return out[:n]
