"""Fused E2LSH hashing kernel: ``floor((x @ a + b*w) / w)`` → int32 codes.

Hashing is the first hot loop of both the offline build and every online
query (paper §4.2). The matmul runs on the MXU; quantization fuses into the
same VMEM tile so raw projections never round-trip through HBM.

Grid: (N/bn, F/bf). Block shapes are MXU-aligned (multiples of 128 where the
problem allows). ``d`` (the contraction dim) stays unblocked — the largest
assigned corpus dim (1770) keeps an (bn, d) tile ≤ 2 MiB in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, a_ref, b_ref, w_ref, out_ref):
    x = x_ref[...]                     # (bn, d)
    a = a_ref[...]                     # (d, bf)
    proj = jnp.dot(x, a, preferred_element_type=jnp.float32)
    b = b_ref[...]                     # (bf,)
    w = w_ref[...]                     # (bf,)
    out_ref[...] = jnp.floor((proj + b[None, :] * w[None, :]) / w[None, :]
                             ).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("bn", "bf", "interpret"))
def lsh_hash(x: jax.Array, a: jax.Array, b: jax.Array, w: jax.Array,
             *, bn: int = 256, bf: int = 128, interpret: bool = True
             ) -> jax.Array:
    """x (N, d), a (d, F), b (F,), w (F,) → codes (N, F) int32."""
    n, d = x.shape
    f = a.shape[1]
    bn = min(bn, n)
    bf = min(bf, f)
    pad_n = (-n) % bn
    pad_f = (-f) % bf
    xp = jnp.pad(x, ((0, pad_n), (0, 0)))
    ap = jnp.pad(a, ((0, 0), (0, pad_f)))
    bp = jnp.pad(b, (0, pad_f))
    wp = jnp.pad(w, (0, pad_f), constant_values=1.0)
    grid = (xp.shape[0] // bn, ap.shape[1] // bf)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, d), lambda i, j: (i, 0)),
            pl.BlockSpec((d, bf), lambda i, j: (0, j)),
            pl.BlockSpec((bf,), lambda i, j: (j,)),
            pl.BlockSpec((bf,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bn, bf), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((xp.shape[0], ap.shape[1]), jnp.int32),
        interpret=interpret,
    )(xp, ap, bp, wp)
    return out[:n, :f]
