"""Hamming-ring kernel (paper Def. 6/7): distance of the query's hash code to
every unique bucket code — the online replacement for the neighbor lookup
table (DESIGN.md §3). One compare-reduce over a (bb, K) tile per grid step.

Padding rows (beyond ``n_buckets``) are masked to K+1 by the wrapper so they
never join any ring.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(codes_ref, qcode_ref, out_ref):
    codes = codes_ref[...]             # (bb, K) int32
    qcode = qcode_ref[...]             # (K,) int32
    out_ref[...] = jnp.sum((codes != qcode[None, :]).astype(jnp.int32), axis=-1)


@functools.partial(jax.jit, static_argnames=("bb", "interpret"))
def hamming(bucket_codes: jax.Array, qcode: jax.Array, *, bb: int = 1024,
            interpret: bool = True) -> jax.Array:
    """bucket_codes (B, K) int32, qcode (K,) → (B,) int32 distances."""
    b, k = bucket_codes.shape
    bb = min(bb, b)
    pad_b = (-b) % bb
    cp = jnp.pad(bucket_codes, ((0, pad_b), (0, 0)))
    grid = (cp.shape[0] // bb,)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, k), lambda i: (i, 0)),
            pl.BlockSpec((k,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bb,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((cp.shape[0],), jnp.int32),
        interpret=interpret,
    )(cp, qcode)
    return out[:b]
