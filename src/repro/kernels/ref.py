"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def lsh_hash(x, a, b, w):
    proj = x.astype(jnp.float32) @ a + b[None, :] * w[None, :]
    return jnp.floor(proj / w[None, :]).astype(jnp.int32)


def l2dist(x, q):
    return jnp.sum((x[:, None, :] - q[None, :, :]) ** 2, axis=-1)


def adc(codes, lut):
    m = lut.shape[0]
    return jnp.sum(lut[jnp.arange(m), codes], axis=-1)


def hamming(bucket_codes, qcode):
    return jnp.sum((bucket_codes != qcode[None, :]).astype(jnp.int32), axis=-1)
