"""Fault-tolerant checkpointing (DESIGN.md §6).

Layout per step:  <dir>/step_<N>/
    manifest.json          step, config hash, leaf index, completion marker
    shard_<host>.npz       flat leaf arrays owned by this host

Guarantees:
  * atomic publish — everything is written into ``step_<N>.tmp`` and renamed;
    a crash mid-save never corrupts the latest valid checkpoint;
  * restore-latest-valid — directories without a manifest (or failing its
    leaf index check) are skipped, so a torn save falls back to the previous
    step automatically;
  * async save — ``save_async`` snapshots to host memory synchronously (so
    training can mutate params immediately) and writes in a worker thread;
  * data-pipeline cursor and optimizer state ride along with params;
  * retention — keep the newest ``keep`` checkpoints.

On a real multi-host pod each host writes its own addressable shards
(``host`` argument); this container exercises the single-host path and the
multi-host layout in tests.
"""
from __future__ import annotations

import hashlib
import json
import shutil
import threading
import time
from pathlib import Path
from typing import Any

import jax
import numpy as np


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        out[key] = np.asarray(leaf)
    return out


def _unflatten_into(tree: Any, flat: dict[str, np.ndarray]) -> Any:
    paths, treedef = jax.tree_util.tree_flatten_with_path(tree)
    leaves = []
    for path, leaf in paths:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        arr = flat[key]
        assert arr.shape == tuple(leaf.shape), f"{key}: {arr.shape} != {leaf.shape}"
        leaves.append(arr.astype(leaf.dtype) if hasattr(leaf, "dtype") else arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def config_hash(obj: Any) -> str:
    return hashlib.sha256(repr(obj).encode()).hexdigest()[:16]


class CheckpointManager:
    def __init__(self, directory: str | Path, keep: int = 3, host: int = 0,
                 n_hosts: int = 1):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.host = host
        self.n_hosts = n_hosts
        self._worker: threading.Thread | None = None

    # ------------------------------------------------------------- save ----
    def save(self, step: int, state: dict, extra: dict | None = None) -> Path:
        flat = _flatten(state)
        return self._write(step, flat, extra or {})

    def save_async(self, step: int, state: dict, extra: dict | None = None):
        self.wait()   # only one outstanding save
        flat = _flatten(state)   # synchronous device->host snapshot
        self._worker = threading.Thread(
            target=self._write, args=(step, flat, extra or {}), daemon=True)
        self._worker.start()

    def wait(self):
        if self._worker is not None:
            self._worker.join()
            self._worker = None

    def _write(self, step: int, flat: dict, extra: dict) -> Path:
        final = self.dir / f"step_{step:08d}"
        tmp = self.dir / f"step_{step:08d}.tmp"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        np.savez(tmp / f"shard_{self.host}.npz", **flat)
        manifest = {
            "step": step, "time": time.time(), "extra": extra,
            "leaves": sorted(flat.keys()), "n_hosts": self.n_hosts,
            "hosts_done": [self.host],
        }
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)
        self._gc()
        return final

    def _gc(self):
        steps = sorted(self._valid_steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # ---------------------------------------------------------- restore ----
    def _valid_steps(self) -> list[int]:
        out = []
        for p in self.dir.glob("step_*"):
            if p.suffix == ".tmp" or not (p / "manifest.json").exists():
                continue
            try:
                m = json.loads((p / "manifest.json").read_text())
                if (p / f"shard_{self.host}.npz").exists():
                    out.append(int(m["step"]))
            except (json.JSONDecodeError, KeyError):
                continue
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self._valid_steps()
        return steps[-1] if steps else None

    def restore(self, template: dict, step: int | None = None
                ) -> tuple[dict, dict, int] | None:
        """-> (state, extra, step) or None if no valid checkpoint."""
        if step is None:
            step = self.latest_step()
        if step is None:
            return None
        p = self.dir / f"step_{step:08d}"
        manifest = json.loads((p / "manifest.json").read_text())
        with np.load(p / f"shard_{self.host}.npz") as z:
            flat = {k: z[k] for k in z.files}
        assert sorted(flat.keys()) == manifest["leaves"], "leaf index mismatch"
        state = _unflatten_into(template, flat)
        return state, manifest.get("extra", {}), step
