"""E2LSH index with a TPU-native sorted-CSR bucket layout.

Paper §2.2 / §4.2: ``h_{a,b}(o) = floor((a·o + b) / W)`` with ``a`` drawn from
N(0, I) (2-stable) and ``b ~ U[0, W)``. ``K`` functions form one table's
composite code; ``L`` independent tables form the index.

TPU adaptation (DESIGN.md §3): hashing is a single ``(N,d) @ (d, L·K)``
matmul; the C++ hash *table* becomes a dense layout per table:

  * ``order``          (L, N)       point ids sorted by bucket code
  * ``bucket_codes``   (L, N, K)    unique codes, row ``j`` = code of bucket j
  * ``bucket_starts``  (L, N)       CSR offset of bucket j into ``order``
  * ``bucket_sizes``   (L, N)       number of points in bucket j
  * ``n_buckets``      (L,)         number of valid bucket rows

Rows ``j >= n_buckets[l]`` are padding (size 0, code sentinel). The bucket
axis is padded to ``B_max = N`` while tracing (shard_map builds), but a
concrete build TRIMS it to ``max(n_buckets)`` rounded up to a multiple of
256 (DESIGN.md §9) — real indexes use a fraction of N buckets, and every
per-query op on the bucket axis (Hamming compare, ring cumsums,
searchsorted) scales with the padded size.

Raw (pre-division) projections are retained so dynamic updates can recompute
``W`` exactly as paper Alg. 7 (``normalizeW``).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.config import ProberConfig

CODE_SENTINEL = jnp.iinfo(jnp.int32).max


class LSHParams(NamedTuple):
    """The hash functions themselves — shared by every shard of a
    distributed index so codes are globally consistent."""
    a: jax.Array   # (d, L*K) float32, N(0,1) entries
    b: jax.Array   # (L*K,)  float32, U[0, W) at init (rescaled with W)
    w: jax.Array   # (L*K,)  float32, per-function bucket width


class LSHIndex(NamedTuple):
    params: LSHParams
    raw: jax.Array            # (N, L*K) float32 — a·x + b (pre division)
    codes: jax.Array          # (L, N, K) int32 — per-table point codes
    order: jax.Array          # (L, N) int32 — points sorted by bucket
    bucket_codes: jax.Array   # (L, N, K) int32 — unique codes (padded)
    bucket_starts: jax.Array  # (L, N) int32
    bucket_sizes: jax.Array   # (L, N) int32
    n_buckets: jax.Array      # (L,) int32

    @property
    def n_points(self) -> int:
        return self.raw.shape[0]

    @property
    def n_tables(self) -> int:
        return self.codes.shape[0]

    @property
    def n_funcs(self) -> int:
        return self.codes.shape[2]


def init_params(key: jax.Array, dim: int, cfg: ProberConfig) -> LSHParams:
    """Sample the (L·K) hash functions. ``w`` starts at 1 and is normalised
    against the data by :func:`normalize_w` during the build."""
    lk = cfg.n_tables * cfg.n_funcs
    ka, kb = jax.random.split(key)
    a = jax.random.normal(ka, (dim, lk), dtype=jnp.float32)
    b = jax.random.uniform(kb, (lk,), dtype=jnp.float32)  # in [0,1); scaled by w
    w = jnp.ones((lk,), dtype=jnp.float32)
    return LSHParams(a=a, b=b, w=w)


def project(params: LSHParams, x: jax.Array) -> jax.Array:
    """Raw projections ``a·x + b·w`` of shape (..., L*K).

    ``b`` is stored as a fraction of ``w`` so that re-normalising ``w``
    (paper Alg. 7) keeps the offset a valid U[0, W) sample.
    """
    return x.astype(jnp.float32) @ params.a + params.b * params.w


def normalize_w(raw: jax.Array, n_regions: int) -> jax.Array:
    """Paper Alg. 7 ``normalizeW``: per-function width from the min/max of the
    raw projections so each function yields ~``n_regions`` distinct values."""
    lo = jnp.min(raw, axis=0)
    hi = jnp.max(raw, axis=0)
    return jnp.maximum((hi - lo) / float(n_regions), 1e-6)


def quantize(raw: jax.Array, w: jax.Array) -> jax.Array:
    """``floor(raw / W)`` — the E2LSH bucket id per function."""
    return jnp.floor(raw / w).astype(jnp.int32)


def hash_point(params: LSHParams, x: jax.Array, n_tables: int) -> jax.Array:
    """Hash one point (or batch) → (..., L, K) int32 codes."""
    raw = project(params, x)
    codes = quantize(raw, params.w)
    return codes.reshape(*x.shape[:-1], n_tables, -1)


def lexsort_rows(codes: jax.Array) -> jax.Array:
    """Return a permutation sorting rows of ``codes`` (N, K) lexicographically.

    Implemented as K stable sorts from the least-significant column — always
    correct regardless of value range (no bit packing assumptions).
    """
    n = codes.shape[0]
    perm = jnp.arange(n, dtype=jnp.int32)
    for col in range(codes.shape[1] - 1, -1, -1):
        keys = codes[perm, col]
        _, perm = jax.lax.sort((keys, perm), is_stable=True, num_keys=1)
    return perm


def _build_table(codes_t: jax.Array) -> tuple[jax.Array, ...]:
    """Build one table's sorted-CSR layout from (N, K) codes."""
    n = codes_t.shape[0]
    perm = lexsort_rows(codes_t)
    sorted_codes = codes_t[perm]
    # boundary[i] = 1 iff row i starts a new bucket
    prev = jnp.concatenate([sorted_codes[:1] - 1, sorted_codes[:-1]], axis=0)
    boundary = jnp.any(sorted_codes != prev, axis=-1)
    bucket_of_row = jnp.cumsum(boundary) - 1            # (N,) 0-based bucket id
    n_buckets = bucket_of_row[-1] + 1
    # CSR: starts[j] = first row of bucket j (seed with N so .min works);
    # sizes via scatter-add
    starts = jnp.full((n,), n, jnp.int32).at[bucket_of_row].min(
        jnp.arange(n, dtype=jnp.int32), mode="drop")
    sizes = jnp.zeros((n,), jnp.int32).at[bucket_of_row].add(1, mode="drop")
    bucket_codes = jnp.full_like(sorted_codes, CODE_SENTINEL)
    bucket_codes = bucket_codes.at[bucket_of_row].set(sorted_codes, mode="drop")
    return perm.astype(jnp.int32), bucket_codes, starts, sizes, n_buckets.astype(jnp.int32)


def build_index(x: jax.Array, cfg: ProberConfig, key: jax.Array,
                params: LSHParams | None = None) -> LSHIndex:
    """Build the full L-table index over ``x`` (N, d).

    If ``params`` is given (distributed build / updates) the hash functions
    are reused; otherwise they are sampled and ``W`` normalised on ``x``.
    """
    if params is None:
        params = init_params(key, x.shape[-1], cfg)
        raw = project(params, x)
        w = normalize_w(raw, cfg.n_regions)
        params = params._replace(w=w)
        raw = project(params, x)  # offsets rescale with w
    else:
        raw = project(params, x)
    codes = quantize(raw, params.w)                         # (N, L*K)
    codes = codes.reshape(x.shape[0], cfg.n_tables, cfg.n_funcs)
    codes = jnp.swapaxes(codes, 0, 1)                       # (L, N, K)
    order, bcodes, starts, sizes, nb = jax.vmap(_build_table)(codes)
    cap = _static_bucket_cap(nb, x.shape[0])
    return LSHIndex(params=params, raw=raw, codes=codes, order=order,
                    bucket_codes=bcodes[:, :cap], bucket_starts=starts[:, :cap],
                    bucket_sizes=sizes[:, :cap], n_buckets=nb)


def _static_bucket_cap(n_buckets: jax.Array, n: int) -> int:
    """Static bucket-axis length: ``max(n_buckets)`` rounded up to a multiple
    of 256 (shape reuse across similar builds), or ``n`` while tracing —
    trimming needs a concrete value and padding to N is always correct."""
    try:
        m = int(jax.device_get(jnp.max(n_buckets)))
    except jax.errors.ConcretizationTypeError:
        return n
    return min(n, max(256, -(-m // 256) * 256))


def hamming_to_buckets(bucket_codes: jax.Array, n_buckets: jax.Array,
                       qcode: jax.Array) -> jax.Array:
    """Hamming distance (paper Def. 6) from the query's code to every unique
    bucket code of one table. Padding rows get ``K+1`` (never probed).

    This one vectorised (B, K) compare-reduce *is* the neighbor lookup on
    TPU — rings N_k are recovered as ``dist == k`` masks (DESIGN.md §3).
    """
    k = bucket_codes.shape[-1]
    dist = jnp.sum(bucket_codes != qcode[None, :], axis=-1).astype(jnp.int32)
    valid = jnp.arange(bucket_codes.shape[0]) < n_buckets
    return jnp.where(valid, dist, k + 1)
