"""E2LSH index with a TPU-native sorted-CSR bucket layout.

Paper §2.2 / §4.2: ``h_{a,b}(o) = floor((a·o + b) / W)`` with ``a`` drawn from
N(0, I) (2-stable) and ``b ~ U[0, W)``. ``K`` functions form one table's
composite code; ``L`` independent tables form the index.

TPU adaptation (DESIGN.md §3): hashing is a single ``(N,d) @ (d, L·K)``
matmul; the C++ hash *table* becomes a dense layout per table:

  * ``order``          (L, C)       point ids sorted by bucket code
  * ``bucket_codes``   (L, B, K)    unique codes, row ``j`` = code of bucket j
  * ``bucket_starts``  (L, B)       CSR offset of bucket j into ``order``
  * ``bucket_sizes``   (L, B)       number of points in bucket j
  * ``n_buckets``      (L,)         number of valid bucket rows
  * ``n_valid``        ()           number of live points (<= capacity C)

Rows ``j >= n_buckets[l]`` are padding (size 0, code sentinel). The bucket
axis is padded to ``B_max = C`` while tracing (shard_map builds), but a
concrete *static* build TRIMS it to ``max(n_buckets)`` rounded up to a
multiple of 256 (DESIGN.md §9) — real indexes use a fraction of C buckets,
and every per-query op on the bucket axis (Hamming compare, ring cumsums,
searchsorted) scales with the padded size.

Capacity padding (DESIGN.md §10): arrays are sized to a *capacity* C that
may exceed the live point count ``n_valid``. Padding point rows carry
``CODE_SENTINEL`` codes, so after the lexsort they collapse into one
trailing sentinel bucket at row ``n_buckets`` — masked out of every probe by
the existing ``j < n_buckets`` convention. A capacity-padded index keeps the
bucket axis untrimmed (B = C) so in-capacity dynamic updates are fixed-shape
jitted steps (updates.py) that never recompile; capacity grows by amortized
doubling (:func:`grow_capacity`), recompiling once per doubling.

Raw projections are retained so dynamic updates can recompute ``W`` exactly
as paper Alg. 7 (``normalizeW``). ``LSHIndex.raw`` stores the PURE
projection ``a·x`` (no ``b·W`` offset): the offset is a per-function
constant, so it cancels out of Alg. 7's ``hi - lo`` mathematically — and
keeping it out of the stored array makes it cancel *bitwise* too. An ingest
that extends no projection extreme then reproduces ``W`` exactly (no
ulp-level drift from re-adding a rescaled offset), which is what lets the
serving cache's epoch invalidation (DESIGN.md §12) treat "W unchanged" as
"code geometry unchanged" instead of flushing on every ingest.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.config import ProberConfig

CODE_SENTINEL = jnp.iinfo(jnp.int32).max


class LSHParams(NamedTuple):
    """The hash functions themselves — shared by every shard of a
    distributed index so codes are globally consistent."""
    a: jax.Array   # (d, L*K) float32, N(0,1) entries
    b: jax.Array   # (L*K,)  float32, U[0, W) at init (rescaled with W)
    w: jax.Array   # (L*K,)  float32, per-function bucket width


class LSHIndex(NamedTuple):
    params: LSHParams
    raw: jax.Array            # (C, L*K) float32 — a·x + b (pre division)
    codes: jax.Array          # (L, C, K) int32 — per-point codes (padding
                              #   rows hold CODE_SENTINEL)
    order: jax.Array          # (L, C) int32 — points sorted by bucket
    bucket_codes: jax.Array   # (L, B, K) int32 — unique codes (padded)
    bucket_starts: jax.Array  # (L, B) int32
    bucket_sizes: jax.Array   # (L, B) int32
    n_buckets: jax.Array      # (L,) int32
    n_valid: jax.Array        # () int32 — live points (rows < n_valid)

    @property
    def capacity(self) -> int:
        return self.raw.shape[0]

    @property
    def n_points(self) -> int:
        """Static row capacity of the layout (== live count for a plain
        build; live count is the ``n_valid`` array for padded indexes)."""
        return self.raw.shape[0]

    @property
    def n_tables(self) -> int:
        return self.codes.shape[0]

    @property
    def n_funcs(self) -> int:
        return self.codes.shape[2]


def init_params(key: jax.Array, dim: int, cfg: ProberConfig) -> LSHParams:
    """Sample the (L·K) hash functions. ``w`` starts at 1 and is normalised
    against the data by :func:`normalize_w` during the build."""
    lk = cfg.n_tables * cfg.n_funcs
    ka, kb = jax.random.split(key)
    a = jax.random.normal(ka, (dim, lk), dtype=jnp.float32)
    b = jax.random.uniform(kb, (lk,), dtype=jnp.float32)  # in [0,1); scaled by w
    w = jnp.ones((lk,), dtype=jnp.float32)
    return LSHParams(a=a, b=b, w=w)


def project(params: LSHParams, x: jax.Array) -> jax.Array:
    """Offset projections ``a·x + b·w`` of shape (..., L*K) — what
    :func:`quantize` divides by ``w`` to get bucket ids.

    ``b`` is stored as a fraction of ``w`` so that re-normalising ``w``
    (paper Alg. 7) keeps the offset a valid U[0, W) sample.
    """
    return project_raw(params, x) + params.b * params.w


def project_raw(params: LSHParams, x: jax.Array) -> jax.Array:
    """Pure projections ``a·x`` (..., L*K) — offset-free, so independent of
    ``w``. This is what the index retains (``LSHIndex.raw``) and what
    Alg. 7's ``normalizeW`` reduces over: min/max of ``a·x`` are exactly
    reproducible across ingests, so ``W`` only moves when an extreme
    actually moves (see module docstring)."""
    return x.astype(jnp.float32) @ params.a


def normalize_w(raw: jax.Array, n_regions: int,
                n_valid: jax.Array | None = None,
                axis_name=None) -> jax.Array:
    """Paper Alg. 7 ``normalizeW``: per-function width from the min/max of the
    raw (pure ``a·x``) projections so each function yields ~``n_regions``
    distinct values. Offset-free inputs make the result bitwise-reproducible
    across ingests whose points extend no extreme (module docstring).

    ``n_valid`` masks capacity-padding rows (DESIGN.md §10) out of the
    min/max so dead rows never influence the bucket widths. Under shard_map
    (DESIGN.md §4) ``axis_name`` pools the extremes across the data shards
    with a pmin/pmax, so a sharded ingest renormalises ``W`` from the
    min/max of ALL live projections — exactly the global Alg. 7 semantics —
    and every shard keeps bit-identical hash functions.
    """
    if n_valid is None:
        lo = jnp.min(raw, axis=0)
        hi = jnp.max(raw, axis=0)
    else:
        valid = (jnp.arange(raw.shape[0]) < n_valid)[:, None]
        lo = jnp.min(jnp.where(valid, raw, jnp.inf), axis=0)
        hi = jnp.max(jnp.where(valid, raw, -jnp.inf), axis=0)
    if axis_name is not None:
        lo = jax.lax.pmin(lo, axis_name)
        hi = jax.lax.pmax(hi, axis_name)
    return jnp.maximum((hi - lo) / float(n_regions), 1e-6)


def quantize(raw: jax.Array, w: jax.Array) -> jax.Array:
    """``floor(raw / W)`` — the E2LSH bucket id per function."""
    return jnp.floor(raw / w).astype(jnp.int32)


def hash_point(params: LSHParams, x: jax.Array, n_tables: int) -> jax.Array:
    """Hash one point (or batch) → (..., L, K) int32 codes."""
    raw = project(params, x)
    codes = quantize(raw, params.w)
    return codes.reshape(*x.shape[:-1], n_tables, -1)


_PACK_BITS = 6            # per-column field width for the packed fast path
_PACK_COLS = 30 // _PACK_BITS   # columns per uint32 key word (30 bits used)


def _pack_fits(codes: jax.Array,
               valid: jax.Array | None = None) -> jax.Array:
    """Scalar predicate: every column's live code range fits the packed
    6-bit sort field. ``codes`` is (..., N, K) — the reduction spans every
    leading (table) axis so ONE unbatched boolean can steer the
    ``lax.cond`` in :func:`lexsort_rows` under vmap (a batched predicate
    would make vmap execute BOTH branches and pay the K-pass fallback on
    every call)."""
    if valid is None:
        lo = jnp.min(codes, axis=-2)
        hi = jnp.max(codes, axis=-2)
    else:
        imax, imin = jnp.iinfo(jnp.int32).max, jnp.iinfo(jnp.int32).min
        v = valid[:, None]
        lo = jnp.min(jnp.where(v, codes, imax), axis=-2)
        hi = jnp.max(jnp.where(v, codes, imin), axis=-2)
    # float diff: an int32 subtraction could wrap for sentinel-sized ranges
    rng = hi.astype(jnp.float32) - lo.astype(jnp.float32)
    return jnp.all(rng < (1 << _PACK_BITS)) & jnp.all(rng >= 0)


def lexsort_rows(codes: jax.Array,
                 valid: jax.Array | None = None,
                 fits: jax.Array | None = None) -> jax.Array:
    """Return a permutation sorting rows of ``codes`` (N, K) lexicographically.

    Fast path (the ingest hot loop, DESIGN.md §10): E2LSH codes under
    ``normalizeW`` span only ~``n_regions`` values per function, so each
    column is rank-compressed to a 6-bit field and 5 columns pack into one
    uint32 sort key — ONE stable ``lax.sort`` on ``ceil(K/5)`` key words
    replaces K column passes. Rows masked out by ``valid`` (capacity
    padding) are excluded from the range check and get all-ones keys, so
    they sort past every live row — exactly where their ``CODE_SENTINEL``
    codes would land. A ``lax.cond`` falls back to the always-correct
    K-pass column sort when any live column's range exceeds the field
    (both branches compile once; the data picks at run time). Vmapped
    callers (the per-table build) must pass an UNBATCHED ``fits``
    (:func:`_pack_fits` over all tables at once) so the cond stays a real
    branch under vmap.
    """
    n, k = codes.shape
    perm = jnp.arange(n, dtype=jnp.int32)

    def generic(_):
        p = perm
        for col in range(k - 1, -1, -1):
            keys = codes[p, col]
            _, p = jax.lax.sort((keys, p), is_stable=True, num_keys=1)
        return p

    nkeys = -(-k // _PACK_COLS)
    if nkeys > 4:                       # huge K: packing saves little
        return generic(None)

    if fits is None:
        fits = _pack_fits(codes, valid)
    if valid is None:
        lo = jnp.min(codes, axis=0)
    else:                               # dead rows don't constrain the range
        lo = jnp.min(jnp.where(valid[:, None], codes,
                               jnp.iinfo(jnp.int32).max), axis=0)

    def packed(_):
        shifted = jnp.clip(codes - lo[None, :], 0,
                           (1 << _PACK_BITS) - 1).astype(jnp.uint32)
        dead = jnp.zeros((n,), jnp.bool_) if valid is None else ~valid
        keys = []
        for g in range(nkeys):
            cols = shifted[:, g * _PACK_COLS:(g + 1) * _PACK_COLS]
            acc = jnp.zeros((n,), jnp.uint32)
            for j in range(cols.shape[1]):
                acc = (acc << _PACK_BITS) | cols[:, j]
            keys.append(jnp.where(dead, jnp.uint32(0xFFFFFFFF), acc))
        out = jax.lax.sort((*keys, perm), is_stable=True, num_keys=nkeys)
        return out[-1]

    return jax.lax.cond(fits, packed, generic, None)


def _build_table(codes_t: jax.Array,
                 n_valid: jax.Array | None = None,
                 fits: jax.Array | None = None) -> tuple[jax.Array, ...]:
    """Build one table's sorted-CSR layout from (C, K) codes.

    With ``n_valid`` (DESIGN.md §10), rows ``>= n_valid`` are capacity
    padding: their codes are forced to ``CODE_SENTINEL`` so they lexsort
    past every live code into a single trailing sentinel bucket, and
    ``n_buckets`` counts live buckets only — the sentinel bucket lands at
    row ``n_buckets`` where the ``j < n_buckets`` probe mask ignores it.
    """
    n = codes_t.shape[0]
    valid = None
    if n_valid is not None:
        valid = jnp.arange(n) < n_valid
        codes_t = jnp.where(valid[:, None], codes_t, CODE_SENTINEL)
    perm = lexsort_rows(codes_t, valid=valid, fits=fits)
    sorted_codes = codes_t[perm]
    # boundary[i] = 1 iff row i starts a new bucket
    prev = jnp.concatenate([sorted_codes[:1] - 1, sorted_codes[:-1]], axis=0)
    boundary = jnp.any(sorted_codes != prev, axis=-1)
    bucket_of_row = jnp.cumsum(boundary) - 1            # (C,) 0-based bucket id
    if n_valid is None:
        n_buckets = bucket_of_row[-1] + 1
    else:
        last = bucket_of_row[jnp.maximum(n_valid - 1, 0)]
        n_buckets = jnp.where(n_valid > 0, last + 1, 0)
    # CSR: starts[j] = first row of bucket j (seed with N so .min works);
    # sizes via scatter-add
    starts = jnp.full((n,), n, jnp.int32).at[bucket_of_row].min(
        jnp.arange(n, dtype=jnp.int32), mode="drop")
    sizes = jnp.zeros((n,), jnp.int32).at[bucket_of_row].add(1, mode="drop")
    bucket_codes = jnp.full_like(sorted_codes, CODE_SENTINEL)
    bucket_codes = bucket_codes.at[bucket_of_row].set(sorted_codes, mode="drop")
    return perm.astype(jnp.int32), bucket_codes, starts, sizes, n_buckets.astype(jnp.int32)


def build_index(x: jax.Array, cfg: ProberConfig, key: jax.Array,
                params: LSHParams | None = None,
                n_valid: jax.Array | int | None = None) -> LSHIndex:
    """Build the full L-table index over ``x`` (C, d).

    If ``params`` is given (distributed build / updates) the hash functions
    are reused; otherwise they are sampled and ``W`` normalised on ``x``.

    If ``n_valid`` is given (DESIGN.md §10), rows ``>= n_valid`` of ``x``
    are capacity padding: they are masked out of the W normalisation,
    their codes become ``CODE_SENTINEL``, and the bucket axis stays
    untrimmed (B = C) so the layout's shapes are a pure function of the
    capacity — the contract the jitted update steps rely on.
    """
    nv = None if n_valid is None else jnp.asarray(n_valid, jnp.int32)
    if params is None:
        params = init_params(key, x.shape[-1], cfg)
        raw = project_raw(params, x)                        # pure a·x
        params = params._replace(w=normalize_w(raw, cfg.n_regions, nv))
    else:
        raw = project_raw(params, x)
    n = x.shape[0]
    codes = quantize(raw + params.b * params.w, params.w)   # (C, L*K)
    codes = codes.reshape(n, cfg.n_tables, cfg.n_funcs)
    codes = jnp.swapaxes(codes, 0, 1)                       # (L, C, K)
    if nv is not None:
        codes = jnp.where((jnp.arange(n) < nv)[None, :, None], codes,
                          CODE_SENTINEL)
    fits = _pack_fits(codes, None if nv is None else (jnp.arange(n) < nv))
    order, bcodes, starts, sizes, nb = jax.vmap(
        _build_table, in_axes=(0, None, None))(codes, nv, fits)
    cap = _static_bucket_cap(nb, n) if nv is None else n
    return LSHIndex(params=params, raw=raw, codes=codes, order=order,
                    bucket_codes=bcodes[:, :cap], bucket_starts=starts[:, :cap],
                    bucket_sizes=sizes[:, :cap], n_buckets=nb,
                    n_valid=jnp.asarray(n if nv is None else nv, jnp.int32))


def _static_bucket_cap(n_buckets: jax.Array, n: int) -> int:
    """Static bucket-axis length: ``max(n_buckets)`` rounded up to a multiple
    of 256 (shape reuse across similar builds), or ``n`` while tracing —
    trimming needs a concrete value and padding to N is always correct."""
    try:
        m = int(jax.device_get(jnp.max(n_buckets)))
    except jax.errors.ConcretizationTypeError:
        return n
    return min(n, max(256, -(-m // 256) * 256))


def grow_capacity(index: LSHIndex, new_capacity: int) -> LSHIndex:
    """Re-pad an index to a larger capacity (DESIGN.md §10).

    The live rows keep their raw projections and codes verbatim; the new
    padding rows join the sentinel bucket. The bucket axis is widened to the
    new capacity (untrimmed), so the result is the fixed-shape layout the
    jitted ingest steps consume. Compiles once per capacity — amortized
    O(log N) compilations under doubling growth.
    """
    cap = index.raw.shape[0]
    assert new_capacity >= cap, (new_capacity, cap)
    pad = new_capacity - cap
    raw = jnp.pad(index.raw, ((0, pad), (0, 0)))
    codes = jnp.pad(index.codes, ((0, 0), (0, pad), (0, 0)),
                    constant_values=CODE_SENTINEL)
    fits = _pack_fits(codes, jnp.arange(new_capacity) < index.n_valid)
    order, bcodes, starts, sizes, nb = jax.vmap(
        _build_table, in_axes=(0, None, None))(codes, index.n_valid, fits)
    return LSHIndex(params=index.params, raw=raw, codes=codes, order=order,
                    bucket_codes=bcodes, bucket_starts=starts,
                    bucket_sizes=sizes, n_buckets=nb, n_valid=index.n_valid)


def hamming_to_buckets(bucket_codes: jax.Array, n_buckets: jax.Array,
                       qcode: jax.Array) -> jax.Array:
    """Hamming distance (paper Def. 6) from the query's code to every unique
    bucket code of one table. Padding rows get ``K+1`` (never probed).

    This one vectorised (B, K) compare-reduce *is* the neighbor lookup on
    TPU — rings N_k are recovered as ``dist == k`` masks (DESIGN.md §3).
    ``n_buckets`` excludes the capacity-padding sentinel bucket (DESIGN.md
    §10), so dead points can never join a ring.
    """
    k = bucket_codes.shape[-1]
    dist = jnp.sum(bucket_codes != qcode[None, :], axis=-1).astype(jnp.int32)
    valid = jnp.arange(bucket_codes.shape[0]) < n_buckets
    return jnp.where(valid, dist, k + 1)
