"""Configuration for the Dynamic Prober (paper §4).

All sizes that shape arrays are static Python ints so everything jits with
fixed shapes. ``a = ln(1/delta)`` is the Chernoff confidence constant from
paper §4.5 (their running example uses delta = 1e-3, i.e. a = ln(1000)).
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class ProberConfig:
    # --- LSH index (paper §2.2, §4.2) ---
    n_tables: int = 2          # L hash tables
    n_funcs: int = 10          # K hash functions per table
    n_regions: int = 4         # target distinct values per function (Ex. 4.1)
    # --- adaptive probing (paper §4.3/4.4, Alg. 1) ---
    max_visit: int = 8192      # maxVisit: total candidate budget across rings
    ring_budget: int = 4096    # R_max: max candidates gathered per ring
    central_budget: int = 4096 # cap for the exact central-bucket pass (Alg. 3)
    # --- progressive sampling (paper §4.5, Alg. 2) ---
    s1: float = 0.05           # initial sampling rate
    s_max: float = 1.0         # maximum sampling rate
    eps: float = 0.01          # error-bound parameter epsilon
    delta: float = 1e-3        # failure probability (a = ln(1/delta))
    chunk: int = 256           # candidates evaluated per while_loop iteration
    schedule_checks: bool = True   # bound checks only at s_{i+1}=2 s_i points
    # --- PQ / ADC (paper §4.6, Alg. 4/5) ---
    use_pq: bool = False
    pq_m: int = 8              # M subspaces
    pq_kc: int = 16            # Kc centroids per subspace
    pq_iters: int = 8          # Lloyd iterations at build
    pq_int8_lut: bool = False  # quantized ADC datapath (DESIGN.md §11):
                               # per-query affine uint8 LUT + int32 accumulate,
                               # threshold compared in the quantized domain.
                               # Qualification matches float32 ADC exactly
                               # outside a ±(M/2+1)·scale band around tau^2.
                               # Ignored when pq_banded (band needs floats).
    pq_pack4: bool = False     # pack two 4-bit PQ codes per byte (requires
                               # Kc <= 16 and even M) — halves code-matrix
                               # bandwidth in the hot loop (DESIGN.md §11)
    pq_banded: bool = False    # residual-banded ADC qualification — measured
                               # WORSE than the hard threshold once near rings
                               # are exact (see EXPERIMENTS.md §Perf); kept as
                               # an option. False = paper-faithful hard test.
    pq_exact_rings: int = 2    # beyond-paper: rings k <= this use exact L2
                               # (near rings carry the selectivity mass —
                               # paper Fig. 1); 0 = ADC everywhere (faithful)
    pq_exact_central: bool = True  # Alg. 3 brute-forces B_central with exact
                               # L2 (paper-faithful). False = ADC there too:
                               # the whole estimate then runs off the byte
                               # codes, never touching the float corpus — the
                               # high-throughput serving trade (DESIGN.md §9)
    # --- skew-resilient probe scheduling (DESIGN.md §11) ---
    lane_block: int = 4        # slab iterations run between lane compactions
                               # of the batched prober; 0 = monolithic
                               # while_loop (no compaction). Results are
                               # bit-identical for every value.
    lane_tile: int = 16        # lanes processed per compacted tile — work
                               # granularity after compaction (static shape).
                               # Batches with Q·L <= lane_tile lanes stay on
                               # the monolithic loop (one tile can't retire
                               # work early, so compacting it is overhead).
                               # Tiles run SEQUENTIALLY, so size this toward
                               # the backend's parallel width: 16 suits the
                               # CPU host measured in DESIGN.md §11; on a
                               # wide-parallel backend (GPU/TPU) raise it
                               # (or set lane_block=0) so compaction never
                               # trades free lane parallelism for depth
    # --- neighbor lookup (paper §4.7, Alg. 6) ---
    table_max_dist: int = 6    # M: distances above this are not stored
    # --- dynamic updates / serving ingest (paper §5, DESIGN.md §10) ---
    ingest_chunk: int = 256    # serve-layer ingest batch: pending points are
                               # applied in fixed chunks of this size so the
                               # jitted in-capacity update step never sees a
                               # new shape (power of two recommended)
    # --- kernels ---
    use_kernels: bool = False  # route hot loops through the Pallas kernels
                               # (native on TPU; interpret=True elsewhere —
                               # correct but slow, so off by default on CPU)

    @property
    def a_const(self) -> float:
        return math.log(1.0 / self.delta)

    def replace(self, **kw) -> "ProberConfig":
        return dataclasses.replace(self, **kw)
