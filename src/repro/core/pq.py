"""Product quantization with asymmetric distance computation (paper §2.2/§4.6).

A vector is split into ``M`` subvectors of dim ``ds = d/M``; each subspace is
k-means-clustered into ``Kc`` centroids; a point is stored as its (M,) int32
codeword. ADC (Alg. 4/5): per query build a lookup table
``T[m, c] = ||q_m - centroid[m, c]||^2`` once, then every point distance is
``sum_m T[m, code[p, m]]`` — squared-L2 convention throughout (DESIGN.md §3:
thresholds compare ``dist^2 <= tau^2`` so no sqrt is ever taken).

K-means runs fully vectorised across subspaces; centroid updates use
``segment_sum`` (no (N, M, Kc) one-hot materialisation).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.config import ProberConfig


class PQIndex(NamedTuple):
    centroids: jax.Array   # (M, Kc, ds) float32
    codes: jax.Array       # (C, M) uint8 — Kc <= 256; byte codes keep the
                           # scan cache-resident (DESIGN.md §9)
    counts: jax.Array      # (M, Kc) float32 — for incremental updates (Alg. 8)
    resid: jax.Array       # (C,) float32 — ||x - q(x)|| quantization residual
                           # (beyond-paper: enables banded ADC qualification)
    n_valid: jax.Array     # () int32 — live points; rows >= n_valid of
                           # codes/resid are capacity padding (DESIGN.md §10)
    packed: Optional[jax.Array] = None
                           # (C, M//2) uint8 — two 4-bit codes per byte
                           # (cfg.pq_pack4, Kc <= 16): halves code-matrix
                           # bandwidth in the hot loop (DESIGN.md §11).
                           # None unless built with pq_pack4.

    @property
    def m(self) -> int:
        return self.centroids.shape[0]

    @property
    def kc(self) -> int:
        return self.centroids.shape[1]

    @property
    def capacity(self) -> int:
        return self.codes.shape[0]


def split_subspaces(x: jax.Array, m: int) -> jax.Array:
    """(N, d) -> (N, M, ds)."""
    n, d = x.shape
    assert d % m == 0, f"M={m} must divide d={d}"
    return x.reshape(n, m, d // m)


def assign(centroids: jax.Array, xs: jax.Array) -> jax.Array:
    """Nearest-centroid assignment per subspace. xs: (N, M, ds) -> (N, M)."""
    # dist^2 = |x|^2 - 2 x.c + |c|^2 ; argmin over Kc
    x2 = jnp.sum(xs ** 2, axis=-1, keepdims=True)            # (N, M, 1)
    c2 = jnp.sum(centroids ** 2, axis=-1)                    # (M, Kc)
    xc = jnp.einsum("nms,mks->nmk", xs, centroids)           # (N, M, Kc)
    d2 = x2 - 2.0 * xc + c2[None]
    return jnp.argmin(d2, axis=-1).astype(jnp.int32)


def fit(x: jax.Array, cfg: ProberConfig, key: jax.Array) -> PQIndex:
    """Lloyd's k-means per subspace, vectorised across all M subspaces."""
    m, kc = cfg.pq_m, cfg.pq_kc
    assert kc <= 256, f"Kc={kc} must fit a uint8 code"
    xs = split_subspaces(x, m)                               # (N, M, ds)
    n, _, ds = xs.shape
    init_rows = jax.random.choice(key, n, (kc,), replace=n < kc)
    centroids = jnp.swapaxes(xs[init_rows], 0, 1)            # (M, Kc, ds)

    def step(centroids, _):
        codes = assign(centroids, xs)                        # (N, M)
        seg = (codes + (jnp.arange(m, dtype=jnp.int32) * kc)[None, :]).reshape(-1)
        flat = xs.reshape(n * m, ds)
        sums = jax.ops.segment_sum(flat, seg, num_segments=m * kc)
        cnts = jax.ops.segment_sum(jnp.ones((n * m,), jnp.float32), seg,
                                   num_segments=m * kc)
        sums = sums.reshape(m, kc, ds)
        cnts = cnts.reshape(m, kc)
        new = jnp.where(cnts[..., None] > 0, sums / jnp.maximum(cnts[..., None], 1.0),
                        centroids)
        return new, None

    centroids, _ = jax.lax.scan(step, centroids, None, length=cfg.pq_iters)
    codes = assign(centroids, xs)
    seg = (codes + (jnp.arange(m, dtype=jnp.int32) * kc)[None, :]).reshape(-1)
    counts = jax.ops.segment_sum(jnp.ones((n * m,), jnp.float32), seg,
                                 num_segments=m * kc).reshape(m, kc)
    resid = reconstruction_residual(centroids, codes, xs)
    codes8 = codes.astype(jnp.uint8)
    packed = None
    if cfg.pq_pack4:
        assert kc <= 16 and m % 2 == 0, \
            f"pq_pack4 needs Kc<=16 and even M, got Kc={kc}, M={m}"
        packed = pack_codes(codes8)
    return PQIndex(centroids=centroids, codes=codes8,
                   counts=counts, resid=resid,
                   n_valid=jnp.asarray(n, jnp.int32), packed=packed)


def grow(pq: PQIndex, new_capacity: int) -> PQIndex:
    """Re-pad codes/resid to a larger capacity (DESIGN.md §10). Padding rows
    are zeros — never read, because candidate ids only ever come from valid
    LSH buckets and the scan baseline masks by ``n_valid``."""
    cap = pq.codes.shape[0]
    assert new_capacity >= cap, (new_capacity, cap)
    pad = new_capacity - cap
    packed = None if pq.packed is None else \
        jnp.pad(pq.packed, ((0, pad), (0, 0)))
    return pq._replace(codes=jnp.pad(pq.codes, ((0, pad), (0, 0))),
                       resid=jnp.pad(pq.resid, ((0, pad),)),
                       packed=packed)


def pack_codes(codes: jax.Array) -> jax.Array:
    """Pack 4-bit PQ codes pairwise: (..., M) uint8 → (..., M//2) uint8.

    Byte j holds codes ``2j`` (low nibble) and ``2j+1`` (high nibble) —
    the layout :func:`unpack_codes` and the packed qualfn gathers invert.
    Requires Kc <= 16 (codes < 16) and even M.
    """
    c = codes.astype(jnp.uint8)
    return (c[..., 0::2] | (c[..., 1::2] << 4)).astype(jnp.uint8)


def unpack_codes(packed: jax.Array) -> jax.Array:
    """Inverse of :func:`pack_codes`: (..., M//2) uint8 → (..., M) int32."""
    lo = (packed & 0xF).astype(jnp.int32)
    hi = (packed >> 4).astype(jnp.int32)
    return jnp.stack([lo, hi], axis=-1).reshape(*packed.shape[:-1],
                                                2 * packed.shape[-1])


def reconstruction_residual(centroids: jax.Array, codes: jax.Array,
                            xs: jax.Array) -> jax.Array:
    """||x - q(x)|| per point; xs is (N, M, ds)."""
    m = centroids.shape[0]
    recon = centroids[jnp.arange(m)[None, :], codes]     # (N, M, ds)
    return jnp.sqrt(jnp.sum((xs - recon) ** 2, axis=(-1, -2)))


def adc_table(pq: PQIndex, q: jax.Array) -> jax.Array:
    """Alg. 4: per-query LUT ``T[m, c] = ||q_m - centroid[m,c]||^2`` (M, Kc)."""
    qs = q.reshape(pq.m, -1)                                 # (M, ds)
    diff = qs[:, None, :] - pq.centroids                     # (M, Kc, ds)
    return jnp.sum(diff ** 2, axis=-1)


class QuantLUT(NamedTuple):
    """Affine-quantized per-query ADC LUT (DESIGN.md §11).

    Entry ``(m, c)`` of the float LUT is represented as
    ``offset + scale * q8[m, c]`` with one scalar (scale, offset) per query,
    so the whole table is uint8 — 4× less VMEM/cache than float32, and the
    per-candidate accumulation is an int32 sum of M bytes. Round-to-nearest
    bounds the per-entry error by ``scale/2`` and the summed ADC error by
    ``M·scale/2``.
    """
    q8: jax.Array      # (M, Kc) uint8 (leading Q axis when batched)
    scale: jax.Array   # () float32
    offset: jax.Array  # () float32 — the LUT minimum


def quantize_lut(lut: jax.Array) -> QuantLUT:
    """Affine uint8 quantization of one (M, Kc) float LUT (Alg. 4 output).

    ``scale = (max - min) / 255`` maps the LUT range onto [0, 255];
    round-to-nearest keeps every dequantized entry within ``scale/2`` of
    the float entry (no clipping error: entries lie inside [min, max]).
    """
    lo = jnp.min(lut)
    scale = jnp.maximum((jnp.max(lut) - lo) / 255.0, 1e-20)
    q8 = jnp.clip(jnp.round((lut - lo) / scale), 0.0, 255.0).astype(jnp.uint8)
    return QuantLUT(q8=q8, scale=scale, offset=lo)


def quantized_threshold(qlut: QuantLUT, m: int, tau_sq: jax.Array) -> jax.Array:
    """Threshold for the quantized qualification test (DESIGN.md §11).

    With ``S = Σ_m q8[m, code_m]`` (int32) the dequantized ADC distance is
    ``M·offset + scale·S``, so ``dequant <= tau²  ⇔  S <= u`` with
    ``u = (tau² - M·offset) / scale``. Since S is an integer, comparing
    against ``floor(u)`` is EXACT with respect to the dequantized distances
    — the only disagreement with float32 ADC comes from the ``±M·scale/2``
    LUT rounding, so decisions match float32 exactly for every candidate
    with ``|adc² - tau²| > (M/2 + 1)·scale`` (the +1 absorbs float rounding
    of u itself; proven tight in tests/test_quantized.py).
    """
    u = (tau_sq - m * qlut.offset) / qlut.scale
    return jnp.clip(jnp.floor(u), -1.0, 255.0 * m + 1.0).astype(jnp.int32)


def build_query_lut(pq: PQIndex, q: jax.Array, cfg: ProberConfig):
    """Per-query LUT in the datapath the config asks for: float32 (Alg. 4),
    or the affine uint8 :class:`QuantLUT` when ``cfg.pq_int8_lut`` (banded
    qualification needs float distances, so it keeps the float LUT)."""
    lut = adc_table(pq, q)
    if cfg.pq_int8_lut and not cfg.pq_banded:
        return quantize_lut(lut)
    return lut


def adc_distance(lut: jax.Array, codes: jax.Array) -> jax.Array:
    """Alg. 5: squared ADC distance for codes (..., M) -> (...,).

    ``lut[m, codes[..., m]]`` summed over m — advanced indexing broadcasts
    ``arange(M)`` against the trailing code axis.
    """
    m = lut.shape[0]
    gathered = lut[jnp.arange(m), codes]   # (..., M)
    return jnp.sum(gathered, axis=-1)
