"""Distributed Dynamic Prober via shard_map (DESIGN.md §4).

Cardinality is additive over a dataset partition, so the estimator is
embarrassingly parallel: shard the points over the ("pod","data") mesh axes,
replicate the LSH/PQ *functions* (so codes are globally consistent), run the
full adaptive prober per shard, and ``psum`` the local estimates. All mesh /
shard_map construction goes through :mod:`repro.compat` so the same code
runs on the pinned jax 0.4.37 and on current jax.

Two stopping modes (``estimate_sharded(mode=...)``):
  * ``local`` (default) — each shard applies the ε-stopping to its own
    partition; zero mid-query communication. Guarantee: each shard's local
    selectivity is bounded within ε w.p. 1-δ, so the global absolute error is
    bounded by ε·N w.p. (1-δ)^shards (union bound over shards). Each shard
    runs the skew-resilient compacting scheduler (DESIGN.md §11) on its own
    lanes — compaction decisions are purely shard-local, which is exactly
    why this mode permits them.
  * ``sync``  — per sampling round the (w, w') statistics are pooled with a
    psum so the ε test sees global selectivity (one small collective per
    probed slab; see ``prober.estimate_one_table``). The stopping guarantee
    is ε/δ on the GLOBAL selectivity with no union bound, and pooled
    samples reach each doubling anchor shards-times faster. Sync mode keeps
    the monolithic lockstep while_loop: the in-loop psum requires every
    shard to execute the same slab sequence, so lane compaction — whose
    reordering/trip-count decisions would have to be derived from pooled
    values to stay lockstep — is documented local-mode-only (DESIGN.md
    §11) and ``prober.estimate_batch`` routes ``axis_name`` calls to the
    monolithic loop.

Dynamic updates (DESIGN.md §10 extended to the sharded index): a
capacity-padded ``build_sharded(..., capacity=...)`` leaves spare rows on
every shard, and :func:`update_sharded` routes each arriving batch to the
shards round-robin and applies ONE fixed-shape jitted shard_map ingest step
— per-shard ``n_valid`` live counts, W renormalised from the GLOBAL min/max
(a pmin/pmax inside the step), zero new compilations while every shard stays
in capacity, and amortized-doubling growth of all shards together when one
would overflow.
"""
from __future__ import annotations

from functools import lru_cache

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat
from repro.core import estimator as est_mod
from repro.core import lsh, updates
from repro.core.config import ProberConfig


def _n_shards(mesh: Mesh, data_axes) -> int:
    return int(np.prod([mesh.shape[a] for a in data_axes]))


def _fold_axis_index(key: jax.Array, data_axes) -> jax.Array:
    """Per-shard PRNG key: fold the shard's mesh position into ``key`` so
    shards draw independent PRP round keys / sampling permutations."""
    for ax in data_axes:
        key = jax.random.fold_in(key, jax.lax.axis_index(ax))
    return key


def build_sharded(x_global: jax.Array, cfg: ProberConfig, key: jax.Array,
                  mesh: Mesh, data_axes=("data",),
                  capacity: int | None = None):
    """Build one local index per shard with shared LSH params.

    ``x_global`` is (N, d) with N divisible by the product of ``data_axes``
    sizes. Returns ``(state, params)`` where the state's leaves carry the
    shard dimension first (global leading dim = number of shards).

    ``capacity`` (DESIGN.md §10): GLOBAL row capacity, split evenly over the
    shards — every shard's arrays are padded to ``capacity // n_shards``
    rows so subsequent :func:`update_sharded` calls that fit in the spare
    rows are fixed-shape jitted steps that never recompile.
    """
    # independent keys for the hash functions and the per-shard build
    # sampling — reusing one key here would correlate the LSH projections
    # with the PQ k-means initialisation built from them
    k_params = jax.random.fold_in(key, 0)
    k_build = jax.random.fold_in(key, 1)
    params = lsh.init_params(k_params, x_global.shape[-1], cfg)
    # normalise W on the global dataset (one pass, cheap) so every shard
    # quantises identically — matches Alg. 7's global min/max semantics
    # (pure projections: a later sharded ingest that extends no extreme
    # reproduces this W bitwise, see lsh.py)
    raw = lsh.project_raw(params, x_global)
    params = params._replace(w=lsh.normalize_w(raw, cfg.n_regions))

    shards = _n_shards(mesh, data_axes)
    n = x_global.shape[0]
    assert n % shards == 0, (n, shards)
    cap_shard = None
    if capacity is not None:
        assert capacity % shards == 0, (capacity, shards)
        cap_shard = capacity // shards
        assert cap_shard >= n // shards, (cap_shard, n // shards)

    spec = P(data_axes)
    xs = jax.device_put(x_global, NamedSharding(mesh, spec))

    @compat.shard_map(mesh=mesh, in_specs=(spec, P()), out_specs=spec,
                      check_vma=False)
    def _build(x_local, k):
        k = _fold_axis_index(k, data_axes)
        st = est_mod.build(x_local, cfg, k, params=params,
                           capacity=cap_shard)
        # leading shard axis of size 1 per device -> global leading dim = shards
        return jax.tree_util.tree_map(lambda a: a[None], st)

    state = _build(xs, k_build)
    return state, params


# ------------------------------------------------ sharded dynamic ingest ----

@lru_cache(maxsize=None)
def _sharded_ingest_step(mesh: Mesh, data_axes, cfg: ProberConfig):
    """Jitted fixed-shape shard_map ingest: every shard runs the DESIGN.md
    §10 capacity-padded update on its local slice; the W renormalisation
    pools min/max across shards (one pmin/pmax) so hash codes stay globally
    consistent. Cached per (mesh, axes, cfg) — shapes are the jit cache's
    business, so a steady chunk stream compiles exactly once."""
    spec = P(data_axes)

    def step(st, x_pad, n_new):
        st = jax.tree_util.tree_map(lambda a: a[0], st)   # drop shard axis
        out = est_mod._ingest_core(st, x_pad[0], n_new[0], cfg,
                                   axis_name=data_axes)
        return jax.tree_util.tree_map(lambda a: a[None], out)

    return jax.jit(compat.shard_map(step, mesh=mesh,
                                    in_specs=(spec, spec, spec),
                                    out_specs=spec, check_vma=False))


@lru_cache(maxsize=None)
def _sharded_grow_step(mesh: Mesh, data_axes, new_capacity: int):
    """Amortized-doubling growth of every shard to ``new_capacity`` rows —
    one recompile per doubling, exactly like the single-device path."""
    spec = P(data_axes)

    def step(st):
        st = jax.tree_util.tree_map(lambda a: a[0], st)
        st = est_mod._grow(st, new_capacity)
        return jax.tree_util.tree_map(lambda a: a[None], st)

    return jax.jit(compat.shard_map(step, mesh=mesh, in_specs=(spec,),
                                    out_specs=spec, check_vma=False))


def route_round_robin(x_new: np.ndarray, shards: int, offset: int):
    """Deterministic round-robin routing: global arrival ``j`` goes to shard
    ``(offset + j) % shards``, where ``offset`` is the stream position (total
    points ingested so far) — so any arrival order spreads evenly and the
    placement is a pure function of the stream, replayable for audit."""
    return [x_new[((s - offset) % shards)::shards] for s in range(shards)]


def update_sharded(state, x_new, cfg: ProberConfig, mesh: Mesh,
                   data_axes=("data",), n_valid=None):
    """Sharded §5 data updates (Alg. 7/8 per shard, DESIGN.md §4/§10).

    Routes ``x_new`` to the shards round-robin (balanced, deterministic),
    pads every shard's part to one common power-of-two width, and applies
    ONE fixed-shape jitted shard_map ingest step. While every shard stays
    in capacity this is a cached step — zero new compilations (tested in
    tests/test_sharding.py); an overflowing shard doubles ALL shards first
    (uniform shapes, amortized O(log N) recompiles).

    ``n_valid`` is an optional host-side (shards,) array of live counts so
    streaming callers avoid the device_get sync. Returns
    ``(state, n_valid)`` with the updated host-side counts.
    """
    shards = _n_shards(mesh, data_axes)
    if n_valid is None:
        n_valid = np.asarray(jax.device_get(state.index.n_valid))
    nv = np.asarray(n_valid, np.int64).reshape(shards)
    x_new = np.asarray(x_new, np.float32)
    if x_new.ndim == 1:
        x_new = x_new[None]
    d = x_new.shape[-1]

    parts = route_round_robin(x_new, shards, int(nv.sum()) % shards)
    counts = np.asarray([len(p) for p in parts], np.int64)
    width = updates.next_pow2(max(int(counts.max()), 1))
    x_sh = np.zeros((shards, width, d), np.float32)
    for s, part in enumerate(parts):
        x_sh[s, :len(part)] = part

    cap_shard = state.x.shape[1]
    needed = int((nv + counts).max())
    if needed > cap_shard:
        grow = _sharded_grow_step(mesh, tuple(data_axes),
                                  updates.next_capacity(cap_shard, needed))
        state = grow(state)

    spec = NamedSharding(mesh, P(data_axes))
    step = _sharded_ingest_step(mesh, tuple(data_axes), cfg)
    state = step(state,
                 jax.device_put(x_sh, spec),
                 jax.device_put(counts.astype(np.int32), spec))
    return state, nv + counts


# ------------------------------------------------------ sharded serving ----

@lru_cache(maxsize=None)
def _sharded_estimate_step(mesh: Mesh, data_axes, cfg: ProberConfig,
                           mode: str):
    spec = P(data_axes)

    def _est(st, q_all, t_all, k):
        st = jax.tree_util.tree_map(lambda a: a[0], st)  # drop shard axis
        k = _fold_axis_index(k, data_axes)
        if mode == "sync":
            # pooled stopping: the result is already global and replicated
            return est_mod.estimate_batch_pooled(st, q_all, t_all, cfg, k,
                                                 axis_name=data_axes)
        local = est_mod.estimate_batch(st, q_all, t_all, cfg, k)
        return jax.lax.psum(local, data_axes)

    return jax.jit(compat.shard_map(_est, mesh=mesh,
                                    in_specs=(spec, P(), P(), P()),
                                    out_specs=P(), check_vma=False))


def estimate_sharded(state, qs: jax.Array, taus: jax.Array, cfg: ProberConfig,
                     key: jax.Array, mesh: Mesh, data_axes=("data",),
                     mode: str = "local"):
    """Batched distributed estimation over the sharded index.

    ``mode="local"``: each shard runs the full adaptive prober with its own
    ε-stopping; one psum folds the per-shard estimates (zero mid-query
    communication). ``mode="sync"``: pooled-stopping — the per-round (w, w')
    Chernoff statistics are psum'd so the ε-test sees GLOBAL selectivity
    (``estimator.estimate_batch_pooled``). Both return (Q,) estimates.
    """
    assert mode in ("local", "sync"), mode
    step = _sharded_estimate_step(mesh, tuple(data_axes), cfg, mode)
    return step(state, qs, taus, key)
