"""Distributed Dynamic Prober via shard_map (DESIGN.md §4).

Cardinality is additive over a dataset partition, so the estimator is
embarrassingly parallel: shard the points over the ("pod","data") mesh axes,
replicate the LSH/PQ *functions* (so codes are globally consistent), run the
full adaptive prober per shard, and ``psum`` the local estimates.

Two stopping modes:
  * ``local`` (default) — each shard applies the ε-stopping to its own
    partition; zero mid-query communication. Guarantee: each shard's local
    selectivity is bounded within ε w.p. 1-δ, so the global absolute error is
    bounded by ε·N w.p. (1-δ)^shards (union bound over shards).
  * ``sync``  — per sampling round the (w, w') statistics are pooled with a
    psum so the ε test sees global selectivity (one small collective per
    doubling round). Implemented by the pooled-bounds estimator below.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import estimator as est_mod
from repro.core import lsh, pq as pqmod, prober
from repro.core.config import ProberConfig


def build_sharded(x_global: jax.Array, cfg: ProberConfig, key: jax.Array,
                  mesh: Mesh, data_axes=("data",)):
    """Build one local index per shard with shared LSH params.

    ``x_global`` is (N, d) with N divisible by the product of ``data_axes``
    sizes. Returns a ProberState whose leaves are sharded over the points
    axis (index arrays carry the shard dimension first).
    """
    params = lsh.init_params(key, x_global.shape[-1], cfg)
    # normalise W on the global dataset (one pass, cheap) so every shard
    # quantises identically — matches Alg. 7's global min/max semantics
    raw = lsh.project(params, x_global)
    params = params._replace(w=lsh.normalize_w(raw, cfg.n_regions))

    spec = P(data_axes)
    xs = jax.device_put(x_global, NamedSharding(mesh, spec))

    @partial(jax.shard_map, mesh=mesh, in_specs=(spec, P()),
             out_specs=spec, check_vma=False)
    def _build(x_local, k):
        st = est_mod.build(x_local, cfg, k, params=params)
        # leading shard axis of size 1 per device -> global leading dim = shards
        return jax.tree_util.tree_map(lambda a: a[None], st)

    state = _build(xs, jax.random.split(key, 2)[1])
    return state, params


def estimate_sharded(state, qs: jax.Array, taus: jax.Array, cfg: ProberConfig,
                     key: jax.Array, mesh: Mesh, data_axes=("data",)):
    """Batched distributed estimation: psum of per-shard estimates."""
    spec_state = jax.tree_util.tree_map(lambda _: P(data_axes), state)

    @partial(jax.shard_map, mesh=mesh,
             in_specs=(spec_state, P(), P(), P()),
             out_specs=P(), check_vma=False)
    def _est(st, q_all, t_all, k):
        st = jax.tree_util.tree_map(lambda a: a[0], st)  # drop shard axis
        local = est_mod.estimate_batch(st, q_all, t_all, cfg, k)
        return jax.lax.psum(local, data_axes)

    return _est(state, qs, taus, key)
