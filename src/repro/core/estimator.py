"""DynamicProber — the public API of the paper's contribution.

    state = build(x, cfg, key)                      # offline (Alg. 4/6 + index)
    est   = estimate(state, q, tau, cfg, key)       # online  (Alg. 1/2/3/5)
    ests  = estimate_batch(state, qs, taus, cfg, key)   # batched online path
    state = update(state, x_new, cfg)               # §5      (Alg. 7/8/9)

The state is a pytree (jit/pmap/shard_map friendly). ``use_pq`` switches the
candidate distance function from exact L2 to PQ-ADC ("Dynamic Prober-PQ").

Shapes and semantics of the two online entry points:

* ``estimate(state, q, tau, cfg, key) -> ()`` — one query ``q`` of shape
  (d,) and one radius ``tau`` (scalar); returns the scalar estimate of
  ``|{p : ||p - q|| <= tau}|``.
* ``estimate_batch(state, qs, taus, cfg, key) -> (Q,)`` — ``qs`` of shape
  (Q, d) and ``taus`` of shape (Q,); ``key`` is split into Q per-query keys,
  so the result is bit-identical to Q sequential ``estimate`` calls with
  ``jax.random.split(key, Q)[i]`` (tested in tests/test_batched.py). The
  batch shares one jitted step: the LSH hash matmul, PQ LUT construction and
  the candidate scan are amortised across queries while each query keeps its
  own Chernoff stopping state (DESIGN.md §9).

Error model (paper §4.5): with ``eps`` and ``delta`` from the config, each
ring's progressive sampler stops once the Chernoff interval around the
empirical selectivity is within ``eps`` on both sides, each side holding
with probability ``1 - delta`` (``a = ln(1/delta)``). Smaller ``eps`` /
``delta`` mean more samples and tighter estimates.

Usage::

    import jax, jax.numpy as jnp
    from repro.core import estimator as E
    from repro.core.config import ProberConfig

    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (8192, 128))          # the corpus
    cfg = ProberConfig(n_tables=2, n_funcs=10)
    state = E.build(x, cfg, key)

    est = E.estimate(state, x[0], jnp.float32(9.0), cfg, key)   # one query
    qs, taus = x[:64], jnp.full((64,), 9.0)                     # a batch
    ests = E.estimate_batch(state, qs, taus, cfg, key)          # (64,)
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import lsh, pq as pqmod, prober, updates
from repro.core.config import ProberConfig


class ProberState(NamedTuple):
    index: lsh.LSHIndex
    x: jax.Array                      # (N, d) the dataset (exact distances)
    pq: Optional[pqmod.PQIndex]       # None unless cfg.use_pq


def build(x: jax.Array, cfg: ProberConfig, key: jax.Array,
          params: lsh.LSHParams | None = None) -> ProberState:
    k1, k2 = jax.random.split(key)
    index = lsh.build_index(x, cfg, k1, params=params)
    pq = pqmod.fit(x, cfg, k2) if cfg.use_pq else None
    return ProberState(index=index, x=x, pq=pq)


@partial(jax.jit, static_argnames=("cfg",))
def estimate(state: ProberState, q: jax.Array, tau: jax.Array,
             cfg: ProberConfig, key: jax.Array) -> jax.Array:
    if cfg.use_pq and state.pq is not None:
        lut = pqmod.adc_table(state.pq, q)
        return prober.estimate(state.index, state.x, q, tau, cfg, key,
                               pq_codes=state.pq.codes, pq_lut=lut,
                               pq_resid=state.pq.resid)
    return prober.estimate(state.index, state.x, q, tau, cfg, key)


@partial(jax.jit, static_argnames=("cfg",))
def estimate_batch(state: ProberState, qs: jax.Array, taus: jax.Array,
                   cfg: ProberConfig, key: jax.Array) -> jax.Array:
    """Estimate Q cardinalities in one jitted step (see module docstring)."""
    keys = jax.random.split(key, qs.shape[0])
    if cfg.use_pq and state.pq is not None:
        luts = jax.vmap(lambda q: pqmod.adc_table(state.pq, q))(qs)  # (Q,M,Kc)
        return prober.estimate_batch(state.index, state.x, qs, taus, cfg, keys,
                                     pq_codes=state.pq.codes, pq_luts=luts,
                                     pq_resid=state.pq.resid)
    return prober.estimate_batch(state.index, state.x, qs, taus, cfg, keys)


def update(state: ProberState, x_new: jax.Array, cfg: ProberConfig) -> ProberState:
    """§5 data updates for every component of the framework."""
    index = updates.update_lsh(state.index, x_new, cfg)
    x = jnp.concatenate([state.x, x_new], axis=0)
    pq = updates.update_pq(state.pq, x_new) if state.pq is not None else None
    return ProberState(index=index, x=x, pq=pq)


def true_cardinality(x: jax.Array, q: jax.Array, tau: jax.Array) -> jax.Array:
    """Exact ground truth (for tests/benchmarks)."""
    d2 = jnp.sum((x - q[None, :]) ** 2, axis=-1)
    return jnp.sum(d2 <= jnp.asarray(tau, jnp.float32) ** 2)
