"""DynamicProber — the public API of the paper's contribution.

    state = build(x, cfg, key)                      # offline (Alg. 4/6 + index)
    est   = estimate(state, q, tau, cfg, key)       # online  (Alg. 1/2/3/5)
    ests  = estimate_batch(state, qs, taus, cfg, key)   # batched online path
    state = update(state, x_new, cfg)               # §5      (Alg. 7/8/9)

The state is a pytree (jit/pmap/shard_map friendly). ``use_pq`` switches the
candidate distance function from exact L2 to PQ-ADC ("Dynamic Prober-PQ").

Dynamic serving (DESIGN.md §10): ``build(..., capacity=C)`` produces a
capacity-padded state — arrays sized to C rows with ``n_valid`` live — so
every ``update`` whose points fit in the spare rows is one cached
fixed-shape jitted step (zero new compilations), and ``estimate`` /
``estimate_batch`` keep their compiled steps across updates too (the state's
shapes don't change until a capacity doubling).

Shapes and semantics of the two online entry points:

* ``estimate(state, q, tau, cfg, key) -> ()`` — one query ``q`` of shape
  (d,) and one radius ``tau`` (scalar); returns the scalar estimate of
  ``|{p : ||p - q|| <= tau}|``.
* ``estimate_batch(state, qs, taus, cfg, key) -> (Q,)`` — ``qs`` of shape
  (Q, d) and ``taus`` of shape (Q,); ``key`` is split into Q per-query keys,
  so the result is bit-identical to Q sequential ``estimate`` calls with
  ``jax.random.split(key, Q)[i]`` (tested in tests/test_batched.py). The
  batch shares one jitted step: the LSH hash matmul, PQ LUT construction and
  the candidate scan are amortised across queries while each query keeps its
  own Chernoff stopping state (DESIGN.md §9).

Error model (paper §4.5): with ``eps`` and ``delta`` from the config, each
ring's progressive sampler stops once the Chernoff interval around the
empirical selectivity is within ``eps`` on both sides, each side holding
with probability ``1 - delta`` (``a = ln(1/delta)``). Smaller ``eps`` /
``delta`` mean more samples and tighter estimates.

Usage::

    import jax, jax.numpy as jnp
    from repro.core import estimator as E
    from repro.core.config import ProberConfig

    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (8192, 128))          # the corpus
    cfg = ProberConfig(n_tables=2, n_funcs=10)
    state = E.build(x, cfg, key)

    est = E.estimate(state, x[0], jnp.float32(9.0), cfg, key)   # one query
    qs, taus = x[:64], jnp.full((64,), 9.0)                     # a batch
    ests = E.estimate_batch(state, qs, taus, cfg, key)          # (64,)
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.cache import epochs as cache_epochs
from repro.core import lsh, pq as pqmod, prober, updates
from repro.core.config import ProberConfig


class ProberState(NamedTuple):
    index: lsh.LSHIndex
    x: jax.Array                      # (C, d) the dataset (exact distances;
                                      #   rows >= n_valid are capacity padding)
    pq: Optional[pqmod.PQIndex]       # None unless cfg.use_pq
    epochs: Optional["cache_epochs.EpochState"] = None
                                      # ingest-epoch counters for the serving
                                      # estimate cache (DESIGN.md §12); None
                                      # unless attached via track_epochs /
                                      # attach_epochs — updates bump them
                                      # inside the same fixed-shape step

    @property
    def n_valid(self) -> jax.Array:
        """Live point count — rows below this index are real data
        (DESIGN.md §10)."""
        return self.index.n_valid

    @property
    def capacity(self) -> int:
        return self.x.shape[0]


def build(x: jax.Array, cfg: ProberConfig, key: jax.Array,
          params: lsh.LSHParams | None = None,
          capacity: int | None = None,
          track_epochs: bool = False) -> ProberState:
    """Offline build. With ``capacity`` (DESIGN.md §10) the state is
    capacity-padded: arrays sized to ``capacity`` rows with ``x.shape[0]``
    live, so subsequent :func:`update` calls that fit in the spare rows are
    fixed-shape jitted steps that never recompile. ``track_epochs`` attaches
    the serving cache's ingest-epoch counters (DESIGN.md §12) so every
    update also records which buckets it touched."""
    k1, k2 = jax.random.split(key)
    if capacity is None:
        index = lsh.build_index(x, cfg, k1, params=params)
        pq = pqmod.fit(x, cfg, k2) if cfg.use_pq else None
        state = ProberState(index=index, x=x, pq=pq)
    else:
        n = x.shape[0]
        assert capacity >= n, (capacity, n)
        x_pad = jnp.pad(jnp.asarray(x, jnp.float32),
                        ((0, capacity - n), (0, 0)))
        index = lsh.build_index(x_pad, cfg, k1, params=params, n_valid=n)
        pq = None
        if cfg.use_pq:
            pq = pqmod.grow(pqmod.fit(x, cfg, k2), capacity)
        state = ProberState(index=index, x=x_pad, pq=pq)
    return attach_epochs(state) if track_epochs else state


def attach_epochs(state: ProberState) -> ProberState:
    """Attach (fresh) ingest-epoch state (DESIGN.md §12) so subsequent
    :func:`update` calls maintain it inside the same fixed-shape jitted
    ingest step. Counters start at zero — correct for a cache created at
    (or after) the same moment."""
    return state._replace(epochs=cache_epochs.init_epochs())


@partial(jax.jit, static_argnames=("cfg",))
def estimate(state: ProberState, q: jax.Array, tau: jax.Array,
             cfg: ProberConfig, key: jax.Array) -> jax.Array:
    if cfg.use_pq and state.pq is not None:
        lut = pqmod.build_query_lut(state.pq, q, cfg)
        return prober.estimate(state.index, state.x, q, tau, cfg, key,
                               pq_codes=state.pq.codes, pq_lut=lut,
                               pq_resid=state.pq.resid,
                               pq_packed=state.pq.packed)
    return prober.estimate(state.index, state.x, q, tau, cfg, key)


@partial(jax.jit, static_argnames=("cfg",))
def estimate_batch(state: ProberState, qs: jax.Array, taus: jax.Array,
                   cfg: ProberConfig, key: jax.Array) -> jax.Array:
    """Estimate Q cardinalities in one jitted step (see module docstring)."""
    keys = jax.random.split(key, qs.shape[0])
    if cfg.use_pq and state.pq is not None:
        # (Q, M, Kc) float LUT stack, or batched QuantLUT (DESIGN.md §11)
        luts = jax.vmap(lambda q: pqmod.build_query_lut(state.pq, q, cfg))(qs)
        return prober.estimate_batch(state.index, state.x, qs, taus, cfg, keys,
                                     pq_codes=state.pq.codes, pq_luts=luts,
                                     pq_resid=state.pq.resid,
                                     pq_packed=state.pq.packed)
    return prober.estimate_batch(state.index, state.x, qs, taus, cfg, keys)


@partial(jax.jit, static_argnames=("cfg",))
def estimate_batch_stats(state: ProberState, qs: jax.Array, taus: jax.Array,
                         cfg: ProberConfig, key: jax.Array):
    """:func:`estimate_batch` plus probe provenance: returns
    ``(ests (Q,), probed_k (Q, L), nvisited (Q,))`` where ``probed_k`` is
    the deepest ring each (query, table) lane folded — what the serving
    estimate cache snapshots for epoch invalidation (DESIGN.md §12).
    Estimates are bit-identical to :func:`estimate_batch` with the same
    key."""
    keys = jax.random.split(key, qs.shape[0])
    if cfg.use_pq and state.pq is not None:
        luts = jax.vmap(lambda q: pqmod.build_query_lut(state.pq, q, cfg))(qs)
        return prober.estimate_batch(state.index, state.x, qs, taus, cfg,
                                     keys, pq_codes=state.pq.codes,
                                     pq_luts=luts, pq_resid=state.pq.resid,
                                     pq_packed=state.pq.packed,
                                     with_stats=True)
    return prober.estimate_batch(state.index, state.x, qs, taus, cfg, keys,
                                 with_stats=True)


def estimate_batch_pooled(state: ProberState, qs: jax.Array, taus: jax.Array,
                          cfg: ProberConfig, key: jax.Array,
                          axis_name) -> jax.Array:
    """Distributed "sync" stopping mode (DESIGN.md §4): ``estimate_batch``
    with the per-round (w, w') Chernoff statistics pooled across the shards
    of the mesh axis ``axis_name``, so the ε-test sees GLOBAL selectivity.

    Must be called *inside* a shard_map over ``axis_name`` with ``state``
    holding the local shard (``distributed.estimate_sharded(mode="sync")``
    is the public entry point). Returns the global (Q,) estimates,
    replicated on every shard — no trailing psum needed.
    """
    keys = jax.random.split(key, qs.shape[0])
    axis_name = axis_name if isinstance(axis_name, str) else tuple(axis_name)
    if cfg.use_pq and state.pq is not None:
        luts = jax.vmap(lambda q: pqmod.build_query_lut(state.pq, q, cfg))(qs)
        return prober.estimate_batch(state.index, state.x, qs, taus, cfg,
                                     keys, pq_codes=state.pq.codes,
                                     pq_luts=luts, pq_resid=state.pq.resid,
                                     pq_packed=state.pq.packed,
                                     axis_name=axis_name)
    return prober.estimate_batch(state.index, state.x, qs, taus, cfg, keys,
                                 axis_name=axis_name)


def _ingest_core(state: ProberState, x_pad: jax.Array, n_new: jax.Array,
                 cfg: ProberConfig, axis_name=None) -> ProberState:
    """One fixed-shape §5 update: write the new rows into spare capacity,
    re-run Alg. 7 over the padded layout, and Alg. 8 with residual refresh.
    Every output shape equals the input shape, so in-capacity updates reuse
    one compiled step (DESIGN.md §10). The single shared body for the
    single-device (:func:`update`) and sharded
    (``distributed.update_sharded``) paths — ``axis_name`` pools Alg. 7's W
    renormalisation across that mesh axis (DESIGN.md §4). When the state
    carries epoch counters (DESIGN.md §12) they are bumped here too, so the
    cache-invalidation signal rides the same zero-recompile step."""
    nv = state.index.n_valid
    old_w = state.index.params.w
    x = updates._write_rows(state.x, x_pad, nv, n_new)
    index = updates._lsh_ingest(state.index, x_pad, n_new, cfg,
                                axis_name=axis_name)
    pq = updates._pq_ingest(state.pq, x, x_pad, n_new) \
        if state.pq is not None else None
    ep = updates._epoch_ingest(state.epochs, index, old_w, n_new) \
        if state.epochs is not None else None
    return ProberState(index=index, x=x, pq=pq, epochs=ep)


_ingest_step = jax.jit(_ingest_core, static_argnames=("cfg", "axis_name"))


def _grow(state: ProberState, new_capacity: int) -> ProberState:
    """Amortized-doubling capacity growth: re-pad every per-point array and
    rebuild the (untrimmed) bucket layout at the new capacity. Recompiles —
    by design only O(log N) times over any update stream."""
    cap = state.x.shape[0]
    x = jnp.pad(state.x, ((0, new_capacity - cap), (0, 0)))
    index = lsh.grow_capacity(state.index, new_capacity)
    pq = pqmod.grow(state.pq, new_capacity) if state.pq is not None else None
    # epoch counters are keyed by code VALUE, not row, so growth (which
    # moves no live point and changes no code) carries them verbatim —
    # cache entries stay valid across doublings (DESIGN.md §12)
    return ProberState(index=index, x=x, pq=pq, epochs=state.epochs)


def update(state: ProberState, x_new: jax.Array, cfg: ProberConfig,
           n_valid: int | None = None) -> ProberState:
    """§5 data updates for every component of the framework.

    If the new points fit in spare capacity this is ONE cached jitted step
    — zero new compilations (the recompile-free serving contract, tested in
    tests/test_updates.py). Otherwise capacity doubles first. The batch is
    padded to the next power of two, so at most log2(max batch) ingest
    shapes ever compile per capacity.

    ``n_valid`` is an optional host-side hint of the current live count:
    reading it from the device blocks on the previous step's results, so
    streaming callers (the serve-layer ingest loop) track the count on the
    host and keep dispatch fully async.
    """
    nn = x_new.shape[0]
    nv = int(jax.device_get(state.index.n_valid)) if n_valid is None \
        else int(n_valid)
    cap = state.x.shape[0]
    if nv + nn > cap:
        state = _grow(state, updates.next_capacity(cap, nv + nn))
    x_pad, n_new = updates._pad_batch(x_new)
    return _ingest_step(state, x_pad, n_new, cfg)


def true_cardinality(x: jax.Array, q: jax.Array, tau: jax.Array,
                     n_valid: jax.Array | None = None) -> jax.Array:
    """Exact ground truth (for tests/benchmarks). ``n_valid`` masks the
    capacity-padding rows of a padded corpus."""
    d2 = jnp.sum((x - q[None, :]) ** 2, axis=-1)
    hit = d2 <= jnp.asarray(tau, jnp.float32) ** 2
    if n_valid is not None:
        hit = hit & (jnp.arange(x.shape[0]) < n_valid)
    return jnp.sum(hit)
