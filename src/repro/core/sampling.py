"""Progressive-sampling confidence bounds (paper §4.5 + Appendix 8.2).

With ``w`` samples, ``w'`` of which qualify, the empirical selectivity is
``p_hat = w'/w`` and, with ``a = ln(1/delta)``,

    mu_upper = (sqrt(p_hat + a/2w) + sqrt(a/2w))^2
    mu_lower = max{0, (sqrt(p_hat + 2a/9w) - sqrt(a/2w))^2 - a/18w}

bound the true selectivity ``p`` with confidence ``1 - delta`` each
(Chernoff; Appendix 8.2 proves the upper side).

Stopping conditions (paper eqns (1)/(2)):
  (1) stop sampling this ring : mu_upper - p_hat <= eps  AND  p_hat - mu_lower <= eps
  (2) stop probing entirely   : mu_upper < eps           (sets the PTF flag)

Note: Alg. 2 line 26 prints ``mu_lower - p_hat <= eps`` which is trivially
true (mu_lower <= p_hat); the prose formula (1) is the meaningful test and is
what we implement.
"""
from __future__ import annotations

import jax.numpy as jnp


def mu_upper(p_hat, w, a):
    w = jnp.maximum(w, 1e-9)
    t = a / (2.0 * w)
    return (jnp.sqrt(p_hat + t) + jnp.sqrt(t)) ** 2


def mu_lower(p_hat, w, a):
    w = jnp.maximum(w, 1e-9)
    t = a / (2.0 * w)
    inner = jnp.sqrt(p_hat + 2.0 * a / (9.0 * w)) - jnp.sqrt(t)
    return jnp.maximum(0.0, inner ** 2 - a / (18.0 * w))


def stop_sampling(p_hat, w, a, eps):
    """Condition (1): the CI around p_hat is within eps on both sides."""
    return ((mu_upper(p_hat, w, a) - p_hat) <= eps) & \
           ((p_hat - mu_lower(p_hat, w, a)) <= eps)


def stop_probing(p_hat, w, a, eps):
    """Condition (2): even the upper bound of the selectivity is below eps —
    further (more distant) rings cannot contribute meaningfully."""
    return mu_upper(p_hat, w, a) < eps
