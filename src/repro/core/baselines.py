"""Baselines the paper compares against (§3/§6).

* ``sampling_estimate`` — uniform sampling (the paper's "Sampling 1%").
* ``MLPEstimator``     — a reference-object learned estimator in the spirit
  of MRCE/SimCard: features are distances from the query to R reference
  objects (k-means centroids) plus tau; a small MLP regresses
  log-cardinality. The full SimCard/MRCE systems (hundreds of local DNNs /
  encoder-decoder featurizers, author code + GPUs) are out of scope offline —
  this stand-in reproduces the *class characteristics* the paper argues
  about: needs labeled training data, slow offline phase, degrades under
  large-scale data updates (benchmarks/bench_updates.py, paper Table 5).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import pq as pqmod
from repro.core.config import ProberConfig


@partial(jax.jit, static_argnames=("n_samples",))
def sampling_estimate(x, q, tau, key, n_samples: int, n_valid=None):
    """Uniform-sampling baseline. ``n_valid`` restricts sampling to the live
    prefix of a capacity-padded corpus (DESIGN.md §10); sampling is then
    with replacement (the live count is a traced value)."""
    n = x.shape[0]
    if n_valid is None:
        idx = jax.random.choice(key, n, (n_samples,), replace=False)
        scale = float(n)
    else:
        u = jax.random.uniform(key, (n_samples,))
        idx = jnp.minimum((u * n_valid).astype(jnp.int32), n_valid - 1)
        scale = n_valid.astype(jnp.float32)
    d2 = jnp.sum((x[idx] - q[None]) ** 2, axis=-1)
    frac = jnp.mean((d2 <= tau ** 2).astype(jnp.float32))
    return frac * scale


@jax.jit
def adc_scan_estimate_batch(pq: "pqmod.PQIndex", qs: jax.Array,
                            taus: jax.Array) -> jax.Array:
    """Batched full-ADC-scan baseline: exact count under quantisation.

    One pass over the byte codes serves all Q queries through the batched
    Pallas kernel (``ops.adc_batch``: the (Q, M, Kc) LUT stack stays in
    VMEM while each code tile is read once; DESIGN.md §9). This is the
    non-adaptive counterpart the prober is compared against when the whole
    corpus fits the scan budget — and the regime where coalescing wins by
    the full Q-fold code-tile reuse.
    """
    from repro.kernels import ops
    luts = jax.vmap(lambda q: pqmod.adc_table(pq, q))(qs)    # (Q, M, Kc)
    d2 = ops.adc_batch(pq.codes, luts)                       # (Q, C)
    live = (jnp.arange(pq.codes.shape[0]) < pq.n_valid)[None, :]
    hit = (d2 <= taus[:, None] ** 2) & live                  # mask capacity
    return jnp.sum(hit.astype(jnp.float32), axis=-1)         # padding rows


# ------------------------------------------------------ learned baseline ---

class MLPEstimator(NamedTuple):
    refs: jax.Array        # (R, d) reference objects
    w1: jax.Array
    b1: jax.Array
    w2: jax.Array
    b2: jax.Array
    w3: jax.Array
    b3: jax.Array


def _scale_of(refs):
    # typical inter-reference distance — normalizes features so the MLP is
    # dimension-scale invariant (unnormalized 960/1770-d inputs diverged)
    d = jnp.sqrt(jnp.sum((refs[:, None] - refs[None]) ** 2, axis=-1))
    return jnp.mean(d) + 1e-6


def _features(refs, q, tau):
    scale = _scale_of(refs)
    d = jnp.sqrt(jnp.sum((refs - q[None]) ** 2, axis=-1)) / scale
    t = tau / scale
    return jnp.concatenate([d / (t + 1e-3), jnp.atleast_1d(t),
                            jnp.atleast_1d(jnp.log1p(t))])


def _fwd(m: MLPEstimator, q, tau):
    f = _features(m.refs, q, tau)
    h = jax.nn.relu(f @ m.w1 + m.b1)
    h = jax.nn.relu(h @ m.w2 + m.b2)
    return (h @ m.w3 + m.b3)[0]          # log1p(cardinality)


def mlp_estimate(m: MLPEstimator, q, tau):
    return jnp.expm1(jnp.clip(_fwd(m, q, tau), 0.0, 20.0))


def fit_mlp(x, queries, taus, cards, key, n_refs: int = 16,
            hidden: int = 64, epochs: int = 400, lr: float = 3e-3
            ) -> MLPEstimator:
    """queries (Q,d), taus (Q,T), cards (Q,T) exact labels."""
    cfg = ProberConfig(pq_m=1, pq_kc=n_refs, pq_iters=8)
    pq = pqmod.fit(x, cfg, key)               # k-means via the PQ machinery
    refs = pq.centroids[0]                    # (R, d)
    fdim = n_refs + 2
    k1, k2, k3 = jax.random.split(key, 3)
    m = MLPEstimator(
        refs=refs,
        w1=jax.random.normal(k1, (fdim, hidden)) * (1.0 / jnp.sqrt(fdim)),
        b1=jnp.zeros((hidden,)),
        w2=jax.random.normal(k2, (hidden, hidden)) * (1.0 / jnp.sqrt(hidden)),
        b2=jnp.zeros((hidden,)),
        w3=jax.random.normal(k3, (hidden, 1)) * (1.0 / jnp.sqrt(hidden)),
        b3=jnp.zeros((1,)),
    )
    qf = queries.reshape(-1, queries.shape[-1])
    flat_q = jnp.repeat(qf, taus.shape[1], axis=0)
    flat_t = taus.reshape(-1)
    flat_y = jnp.log1p(cards.reshape(-1).astype(jnp.float32))

    def loss_fn(m):
        pred = jax.vmap(lambda q, t: _fwd(m, q, t))(flat_q, flat_t)
        return jnp.mean((pred - flat_y) ** 2)

    @jax.jit
    def step(m):
        g = jax.grad(loss_fn)(m)
        # clip for stability; refs are data, not trained
        g = g._replace(refs=jnp.zeros_like(g.refs))
        gn = jnp.sqrt(sum(jnp.sum(x * x) for x in jax.tree_util.tree_leaves(g)))
        sc = jnp.minimum(1.0, 10.0 / (gn + 1e-9))
        return jax.tree_util.tree_map(lambda p, gg: p - lr * sc * gg, m, g)

    for _ in range(epochs):
        m = step(m)
    return m
