"""Dynamic data updates (paper §5, Alg. 7/8/9) — recompile-free in-capacity
ingest over the capacity-padded layout (DESIGN.md §10).

* LSH (Alg. 7): hash new points with the *original* functions, re-normalise
  ``W`` from the min/max of ALL live raw projections (old + new — the
  retained ``raw`` array makes this exact), re-quantise and rebuild the
  sorted-CSR layout. The rebuild is one sort — on TPU that IS the hash-table
  update.
* PQ (Alg. 8): assign new points to their nearest existing centroids, move
  the affected centroids to the running mean (counts retained in the index),
  and refresh the quantization residuals of EVERY live point against the
  moved centroids — old points' residuals would otherwise silently refer to
  pre-update centroids and break the banded-ADC triangle bound.
* Neighbor table (Alg. 9): see neighbors.update — new-vs-old / new-vs-new
  blocks only; fixed-shape jittable once the code array is capacity-padded.

Shapes do NOT grow with N: new points are written into spare capacity rows
of the padded layout (`jnp.where`-masked scatters at traced ``n_valid``), so
an in-capacity update is ONE fixed-shape jitted step that never recompiles.
Only a capacity doubling (amortized O(log N) times over any stream) pays a
recompile, and the update batch is padded to a power of two so at most
``log2(batch)`` ingest shapes ever compile. Measured in
benchmarks/bench_updates.py (mirroring paper Fig. 6/7 + the amortized
incremental-throughput sweep).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import lsh, pq as pqmod
from repro.core.config import ProberConfig


def next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


def next_capacity(cap: int, needed: int) -> int:
    """Amortized doubling: smallest power-of-two multiple of ``cap`` (at
    least 256) covering ``needed``."""
    cap = max(cap, 256)
    while cap < needed:
        cap *= 2
    return cap


def _write_rows(dst: jax.Array, src: jax.Array, start: jax.Array,
                n_new: jax.Array) -> jax.Array:
    """Scatter ``src[:n_new]`` into ``dst[start:start+n_new]``.

    ``start``/``n_new`` are traced scalars; rows of ``src`` beyond ``n_new``
    (the power-of-two batch padding) are routed out of bounds and dropped,
    so a padded batch can never clobber live or spare rows it doesn't own.
    """
    nn_pad = src.shape[0]
    slots = jnp.arange(nn_pad, dtype=jnp.int32)
    rows = jnp.where(slots < n_new, start + slots, dst.shape[0])
    return dst.at[rows].set(src, mode="drop")


# ------------------------------------------------------------- LSH (Alg. 7)

def _lsh_ingest(index: lsh.LSHIndex, x_new: jax.Array, n_new: jax.Array,
                cfg: ProberConfig, axis_name=None) -> lsh.LSHIndex:
    """Fixed-shape Alg. 7 step: all output shapes equal the input capacity.

    Requires spare capacity for ``x_new.shape[0]`` rows (the wrapper grows
    first). jit-compiled once per (capacity, batch) shape pair.

    ``axis_name`` (DESIGN.md §4): inside a shard_map over that mesh axis,
    the W renormalisation pools its min/max across shards (one pmin/pmax
    pair per ingest), so every shard derives the same global widths and
    bucket codes stay globally consistent.
    """
    params = index.params
    nv = index.n_valid
    raw_new = lsh.project_raw(params, x_new)          # pure a·x, w-free
    raw_all = _write_rows(index.raw, raw_new, nv, n_new)
    nv2 = nv + n_new
    # normalizeW over ALL live raw projections (old + new). ``raw`` is
    # offset-free, so when the batch extends no extreme this reproduces W
    # BITWISE — old points' codes below are then reproduced bitwise too,
    # which is what lets the serving cache treat "W unchanged" as "bucket
    # geometry unchanged" (DESIGN.md §12)
    w_new = lsh.normalize_w(raw_all, cfg.n_regions, nv2, axis_name=axis_name)
    params = params._replace(w=w_new)
    codes = lsh.quantize(raw_all + params.b * w_new, w_new)
    cap = raw_all.shape[0]
    codes = codes.reshape(cap, cfg.n_tables, cfg.n_funcs)
    codes = jnp.swapaxes(codes, 0, 1)
    codes = jnp.where((jnp.arange(cap) < nv2)[None, :, None], codes,
                      lsh.CODE_SENTINEL)
    fits = lsh._pack_fits(codes, jnp.arange(cap) < nv2)
    order, bcodes, starts, sizes, nb = jax.vmap(
        lsh._build_table, in_axes=(0, None, None))(codes, nv2, fits)
    return lsh.LSHIndex(params=params, raw=raw_all, codes=codes, order=order,
                        bucket_codes=bcodes, bucket_starts=starts,
                        bucket_sizes=sizes, n_buckets=nb, n_valid=nv2)


_lsh_ingest_jit = jax.jit(_lsh_ingest, static_argnames=("cfg", "axis_name"))


def _epoch_ingest(ep, index: lsh.LSHIndex, old_w: jax.Array,
                  n_new: jax.Array):
    """Fold one ingest into the cache-invalidation epoch state (DESIGN.md
    §12) inside the same fixed-shape step as the Alg. 7 rebuild — zero
    extra dispatches, zero-recompile contract intact.

    The per-bucket ingest signal needs no explicit counters: the rebuilt
    layout's ``bucket_sizes`` ARE the per-bucket epochs (populations are
    monotone under the §5 stream — see repro/cache/epochs.py). What must
    be tracked is the hash-function GENERATION: if Alg. 7 moved any width
    (``w != old_w`` — bitwise-exact thanks to the offset-free retained
    projections), every stored code may have shifted and the whole cache
    generation is retired via the params epoch.
    """
    from repro.cache import epochs as cache_epochs
    w_changed = jnp.any(index.params.w != old_w)
    return cache_epochs.ingest_bump(ep, n_new, w_changed)


def _pad_batch(x_new: jax.Array) -> tuple[jax.Array, jax.Array]:
    nn = x_new.shape[0]
    nn_pad = next_pow2(nn)
    x_pad = jnp.pad(jnp.asarray(x_new, jnp.float32),
                    ((0, nn_pad - nn), (0, 0)))
    return x_pad, jnp.asarray(nn, jnp.int32)


def update_lsh(index: lsh.LSHIndex, x_new: jax.Array,
               cfg: ProberConfig) -> lsh.LSHIndex:
    """Alg. 7. Returns an index whose live rows cover the concatenated
    dataset. In-capacity calls dispatch one cached jitted step (zero new
    compilations); otherwise capacity doubles first (amortized)."""
    nn = x_new.shape[0]
    nv = int(jax.device_get(index.n_valid))
    cap = index.raw.shape[0]
    if nv + nn > cap:
        index = lsh.grow_capacity(index, next_capacity(cap, nv + nn))
    x_pad, n_new = _pad_batch(x_new)
    return _lsh_ingest_jit(index, x_pad, n_new, cfg)


# -------------------------------------------------------------- PQ (Alg. 8)

def _pq_ingest(pq: pqmod.PQIndex, x_all: jax.Array, x_new: jax.Array,
               n_new: jax.Array) -> pqmod.PQIndex:
    """Fixed-shape Alg. 8 step over the capacity-padded code/resid arrays.

    ``x_all`` is the capacity-padded corpus WITH the new rows already
    written at ``[n_valid, n_valid + n_new)`` — needed because the moved
    centroids invalidate every affected point's stored residual, so all
    live residuals are recomputed against the post-update centroids.
    """
    m, kc = pq.m, pq.kc
    cap = pq.codes.shape[0]
    nn_pad = x_new.shape[0]
    xs_new = pqmod.split_subspaces(x_new, m)              # (Nn, M, ds)
    ds = xs_new.shape[-1]
    # paper's rule: new points take the nearest of the OLD centroids
    new_codes = pqmod.assign(pq.centroids, xs_new)        # (Nn, M)
    wvalid = (jnp.arange(nn_pad) < n_new)
    seg = (new_codes + (jnp.arange(m, dtype=jnp.int32) * kc)[None, :]).reshape(-1)
    wf = jnp.repeat(wvalid.astype(jnp.float32), m)
    sums = jax.ops.segment_sum(xs_new.reshape(nn_pad * m, ds) * wf[:, None],
                               seg, num_segments=m * kc)
    cnts = jax.ops.segment_sum(wf, seg, num_segments=m * kc)
    sums = sums.reshape(m, kc, ds)
    cnts = cnts.reshape(m, kc)
    tot = pq.counts + cnts
    # running mean: c' = (c*old_count + sum_new) / (old_count + new_count)
    new_centroids = jnp.where(
        tot[..., None] > 0,
        (pq.centroids * pq.counts[..., None] + sums) / jnp.maximum(tot[..., None], 1.0),
        pq.centroids)
    codes = _write_rows(pq.codes, new_codes.astype(pq.codes.dtype),
                        pq.n_valid, n_new)
    packed = pq.packed
    if packed is not None:   # keep the 4-bit mirror in sync (DESIGN.md §11)
        packed = _write_rows(packed,
                             pqmod.pack_codes(new_codes.astype(jnp.uint8)),
                             pq.n_valid, n_new)
    nv2 = pq.n_valid + n_new
    # refresh EVERY live residual against the moved centroids — old points
    # would otherwise keep residuals of the pre-update codebook
    xs_all = pqmod.split_subspaces(x_all, m)
    resid = pqmod.reconstruction_residual(new_centroids,
                                          codes.astype(jnp.int32), xs_all)
    resid = jnp.where(jnp.arange(cap) < nv2, resid, 0.0)
    return pqmod.PQIndex(centroids=new_centroids, codes=codes, counts=tot,
                         resid=resid, n_valid=nv2, packed=packed)


_pq_ingest_jit = jax.jit(_pq_ingest)


def update_pq(pq: pqmod.PQIndex, x_new: jax.Array,
              x_all: jax.Array) -> pqmod.PQIndex:
    """Alg. 8: assign-new + incremental centroid means + residual refresh.

    ``x_all`` must be the full corpus (old points first, then ``x_new``),
    optionally capacity-padded; the PQ arrays are grown to match. Residuals
    of ALL live points are recomputed against the moved centroids.
    """
    nn = x_new.shape[0]
    nv = int(jax.device_get(pq.n_valid))
    cap = x_all.shape[0]
    assert nv + nn <= cap, (nv, nn, cap)
    x_all = jnp.asarray(x_all, jnp.float32)
    if cap < pq.codes.shape[0]:      # exact corpus against padded PQ arrays
        x_all = jnp.pad(x_all, ((0, pq.codes.shape[0] - cap), (0, 0)))
    elif pq.codes.shape[0] < cap:
        pq = pqmod.grow(pq, cap)
    x_pad, n_new = _pad_batch(x_new)
    return _pq_ingest_jit(pq, x_all, x_pad, n_new)
