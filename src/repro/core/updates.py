"""Dynamic data updates (paper §5, Alg. 7/8/9).

* LSH (Alg. 7): hash new points with the *original* functions, re-normalise
  ``W`` from the min/max of ALL raw projections (old + new — the retained
  ``raw`` array makes this exact), re-quantise and rebuild the sorted-CSR
  layout. The rebuild is one sort — on TPU that IS the hash-table update.
* PQ (Alg. 8): assign new points to their nearest existing centroids and move
  the affected centroids to the running mean (counts retained in the index).
* Neighbor table (Alg. 9): see neighbors.update — new-vs-old / new-vs-new
  blocks only.

Shapes grow with N, so updates recompile once per growth step — expected and
cheap relative to an index rebuild from scratch (benchmarked in
benchmarks/bench_updates.py, mirroring paper Fig. 6/7).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import lsh, pq as pqmod
from repro.core.config import ProberConfig


def update_lsh(index: lsh.LSHIndex, x_new: jax.Array,
               cfg: ProberConfig) -> lsh.LSHIndex:
    """Alg. 7. Returns an index over the concatenated dataset."""
    params = index.params
    raw_new = lsh.project(params, x_new)
    raw_all = jnp.concatenate([index.raw, raw_new], axis=0)
    # normalizeW over ALL raw hash values (old + new), then re-divide
    w_new = lsh.normalize_w(raw_all, cfg.n_regions)
    # offsets b are stored as a fraction of w (see lsh.project): rebase the
    # additive offset from b*w_old to b*w_new before re-quantising
    proj = raw_all - params.b * params.w          # pure x @ a
    params = params._replace(w=w_new)
    raw_adj = proj + params.b * w_new
    codes = lsh.quantize(raw_adj, w_new)
    n = raw_all.shape[0]
    codes = codes.reshape(n, cfg.n_tables, cfg.n_funcs)
    codes = jnp.swapaxes(codes, 0, 1)
    order, bcodes, starts, sizes, nb = jax.vmap(lsh._build_table)(codes)
    cap = lsh._static_bucket_cap(nb, n)
    return lsh.LSHIndex(params=params, raw=raw_adj, codes=codes, order=order,
                        bucket_codes=bcodes[:, :cap],
                        bucket_starts=starts[:, :cap],
                        bucket_sizes=sizes[:, :cap], n_buckets=nb)


def update_pq(pq: pqmod.PQIndex, x_new: jax.Array) -> pqmod.PQIndex:
    """Alg. 8: assign-new + incremental centroid means."""
    m, kc = pq.m, pq.kc
    xs = pqmod.split_subspaces(x_new, m)                  # (Nn, M, ds)
    nn, _, ds = xs.shape
    new_codes = pqmod.assign(pq.centroids, xs)            # (Nn, M)
    seg = (new_codes + (jnp.arange(m, dtype=jnp.int32) * kc)[None, :]).reshape(-1)
    sums = jax.ops.segment_sum(xs.reshape(nn * m, ds), seg, num_segments=m * kc)
    cnts = jax.ops.segment_sum(jnp.ones((nn * m,), jnp.float32), seg,
                               num_segments=m * kc)
    sums = sums.reshape(m, kc, ds)
    cnts = cnts.reshape(m, kc)
    tot = pq.counts + cnts
    # running mean: c' = (c*old_count + sum_new) / (old_count + new_count)
    new_centroids = jnp.where(
        tot[..., None] > 0,
        (pq.centroids * pq.counts[..., None] + sums) / jnp.maximum(tot[..., None], 1.0),
        pq.centroids)
    codes = jnp.concatenate([pq.codes, new_codes.astype(pq.codes.dtype)],
                            axis=0)
    new_resid = pqmod.reconstruction_residual(new_centroids, new_codes, xs)
    resid = jnp.concatenate([pq.resid, new_resid], axis=0)
    return pqmod.PQIndex(centroids=new_centroids, codes=codes, counts=tot,
                         resid=resid)
