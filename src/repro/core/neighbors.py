"""Precomputed bucket-neighbor lookup table (paper §4.7, Alg. 6 & Alg. 9).

The online prober (prober.py) computes Hamming rings on the fly — the TPU-
efficient path. This module implements the paper's *literal* offline table for
faithfulness and for the dynamic-update algorithm:

  ``table[i, j] = hamming(C[i], C[j])`` if ``0 < d <= M`` else 0 (not stored)

stored densely as int8 (M <= 127). ``ring(i, k)`` masks ``table[i] == k`` —
bit-identical to the online masks (property-tested).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class NeighborTable(NamedTuple):
    dists: jax.Array    # (B, B) int8 — 0 where not stored (d==0 or d>M)
    n: jax.Array        # () int32 — number of valid codes
    max_dist: int       # static M


def _pairwise_hamming(a: jax.Array, b: jax.Array) -> jax.Array:
    """(Ba, K) x (Bb, K) -> (Ba, Bb) int32 Hamming distances."""
    return jnp.sum(a[:, None, :] != b[None, :, :], axis=-1).astype(jnp.int32)


def build(codes: jax.Array, n_valid: jax.Array, max_dist: int) -> NeighborTable:
    """Alg. 6: all-pairs Hamming over the unique bucket codes ``C``.

    ``codes``: (B, K) padded; rows >= n_valid ignored (distance not stored).
    """
    b = codes.shape[0]
    d = _pairwise_hamming(codes, codes)
    valid = (jnp.arange(b) < n_valid)
    keep = valid[:, None] & valid[None, :] & (d > 0) & (d <= max_dist)
    stored = jnp.where(keep, d, 0).astype(jnp.int8)
    return NeighborTable(dists=stored, n=jnp.asarray(n_valid, jnp.int32),
                         max_dist=max_dist)


def ring(table: NeighborTable, i: jax.Array, k: jax.Array) -> jax.Array:
    """Bucket mask of the k-step neighbors N_k of bucket ``i`` (k >= 1)."""
    return table.dists[i] == k.astype(jnp.int8)


def grow(table: NeighborTable, new_capacity: int) -> NeighborTable:
    """Re-pad the table to a larger code capacity (DESIGN.md §10). Padding
    entries are 0 (= not stored) and sit beyond ``n``, so every ``ring``
    lookup is unchanged."""
    cap = table.dists.shape[0]
    assert new_capacity >= cap, (new_capacity, cap)
    pad = new_capacity - cap
    return table._replace(dists=jnp.pad(table.dists, ((0, pad), (0, pad))))


def update(table: NeighborTable, codes_all: jax.Array, n_old: jax.Array,
           n_new_total: jax.Array) -> NeighborTable:
    """Alg. 9: extend the table with new codes C1 = codes_all[n_old:n_total].

    Computes new-vs-old and new-vs-new blocks only; the old-vs-old block is
    reused untouched (the point of the incremental algorithm). ``codes_all``
    must be the concatenated (B', K) array with the original codes first.

    Capacity-padded path (DESIGN.md §10): when ``codes_all`` shares the
    table's capacity (B' == B, padding rows past ``n_new_total`` carrying
    any value — they are masked), every shape here is fixed and
    ``n_old``/``n_new_total`` may be traced scalars, so the step jits once
    and never recompiles while updates fit in capacity (grow first via
    :func:`grow`).
    """
    b = codes_all.shape[0]
    d = _pairwise_hamming(codes_all, codes_all)
    idx = jnp.arange(b)
    is_old = idx < n_old
    is_new = (idx >= n_old) & (idx < n_new_total)
    # only pairs touching a new code are (re)computed
    touches_new = is_new[:, None] | is_new[None, :]
    valid = (is_old | is_new)[:, None] & (is_old | is_new)[None, :]
    keep = valid & (d > 0) & (d <= table.max_dist)
    old_block = jnp.zeros((b, b), jnp.int8)
    nb = table.dists.shape[0]
    old_block = old_block.at[:nb, :nb].set(table.dists)
    new_vals = jnp.where(keep & touches_new, d, 0).astype(jnp.int8)
    merged = jnp.where(touches_new, new_vals, old_block)
    return NeighborTable(dists=merged, n=jnp.asarray(n_new_total, jnp.int32),
                         max_dist=table.max_dist)
