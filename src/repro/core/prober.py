"""Neighboring-based adaptive bucket probing (paper §4.3/4.4, Alg. 1–3).

TPU-native formulation (DESIGN.md §3): rings N_k are masks over the unique
bucket codes (``hamming == k``); ring candidates are gathered into a static
``ring_budget`` buffer via a cumsum/searchsorted inversion of the sorted-CSR
layout; progressive sampling walks a random permutation of that buffer in
fixed-size chunks inside ``lax.while_loop``, checking the Chernoff bounds of
§4.5 at the doubling schedule points ``s_{i+1} = 2 s_i``.

Everything is shape-static, jit-able and vmap-able over queries.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import lsh, sampling
from repro.core.config import ProberConfig

# qualfn(ids: (c,) int32) -> qualification weight in [0,1] per point
# (exact: 1[d^2 <= tau^2]; banded ADC: interpolated within the residual band)
QualFn = Callable[[jax.Array], jax.Array]


class TableView(NamedTuple):
    """One hash table's slice of the index (leading L axis stripped)."""
    order: jax.Array          # (N,)
    bucket_codes: jax.Array   # (B, K)
    bucket_starts: jax.Array  # (B,)
    bucket_sizes: jax.Array   # (B,)
    n_buckets: jax.Array      # ()


def table_views(index: lsh.LSHIndex) -> TableView:
    """Stacked (L, ...) view suitable for vmap over tables."""
    return TableView(index.order, index.bucket_codes, index.bucket_starts,
                     index.bucket_sizes, index.n_buckets)


def gather_ring(view: TableView, ring_mask: jax.Array, budget: int):
    """Gather up to ``budget`` point ids belonging to masked buckets.

    Returns (ids (budget,), valid (budget,), total ()) where ``total`` is the
    *full* ring population |N_k| (may exceed budget).
    """
    sizes = jnp.where(ring_mask, view.bucket_sizes, 0)
    cum = jnp.cumsum(sizes)
    total = cum[-1]
    slots = jnp.arange(budget, dtype=jnp.int32)
    j = jnp.searchsorted(cum, slots, side="right").astype(jnp.int32)
    j = jnp.minimum(j, cum.shape[0] - 1)
    prev = jnp.where(j > 0, cum[jnp.maximum(j - 1, 0)], 0)
    pos = view.bucket_starts[j] + (slots - prev)
    valid = slots < total
    pos = jnp.clip(jnp.where(valid, pos, 0), 0, view.order.shape[0] - 1)
    return view.order[pos], valid, total


def _count_central(view: TableView, ham: jax.Array, qualfn: QualFn,
                   cfg: ProberConfig):
    """Alg. 3: exact brute-force count inside B_central.

    If the bucket exceeds ``central_budget`` the exact count over the gathered
    prefix is scaled by ``total/seen`` (static-shape cap; DESIGN.md §3).
    """
    ids, valid, total = gather_ring(view, ham == 0, cfg.central_budget)
    qualified = jnp.sum(qualfn(ids) * valid)
    seen = jnp.sum(valid)
    scale = jnp.where(seen > 0, total / jnp.maximum(seen, 1), 0.0)
    return qualified * scale, seen


def _estimate_ring(view: TableView, ring_mask: jax.Array, qualfn: QualFn,
                   cfg: ProberConfig, key: jax.Array):
    """Alg. 2 (f_neighbor): progressive sampling inside one ring N_k.

    Returns (ring_estimate, n_visited, ptf).
    """
    a = cfg.a_const
    ids, valid, total = gather_ring(view, ring_mask, cfg.ring_budget)
    cap = jnp.minimum(total, cfg.ring_budget)  # points actually addressable

    # Random permutation of the valid prefix: invalid slots sink to the end.
    keys = jnp.where(valid, jax.random.uniform(key, (cfg.ring_budget,)), jnp.inf)
    perm = jnp.argsort(keys)
    shuffled = ids[perm]

    chunk = cfg.chunk
    n_chunks = max(cfg.ring_budget // chunk, 1)
    total_f = total.astype(jnp.float32)
    # first schedule point: w_1 = ceil(s1 * |N_k|) (Alg. 2 line 8)
    first_target = jnp.ceil(cfg.s1 * total_f)
    w_cap = jnp.minimum(jnp.ceil(cfg.s_max * total_f), cap.astype(jnp.float32))

    def cond(state):
        ci, w, wq, done, ptf, target = state
        return (ci < n_chunks) & (~done)

    def body(state):
        ci, w, wq, done, ptf, target = state
        sl = jax.lax.dynamic_slice(shuffled, (ci * chunk,), (chunk,))
        slot = ci * chunk + jnp.arange(chunk, dtype=jnp.int32)
        ok = slot < cap
        wq = wq + jnp.sum(qualfn(sl) * ok)
        w = w + jnp.sum(ok)
        wf = w.astype(jnp.float32)
        p_hat = wq / jnp.maximum(wf, 1.0)
        at_schedule = (wf >= target) | (wf >= w_cap)
        if not cfg.schedule_checks:      # static: check bounds every chunk
            at_schedule = jnp.bool_(True)
        cond1 = sampling.stop_sampling(p_hat, wf, a, cfg.eps)
        cond2 = sampling.stop_probing(p_hat, wf, a, cfg.eps)
        new_done = done | (at_schedule & (cond1 | cond2)) | (wf >= w_cap)
        new_ptf = ptf | (at_schedule & cond2)
        target = jnp.where(at_schedule, target * 2.0, target)
        return ci + 1, w, wq, new_done, new_ptf, target

    state = (jnp.int32(0), jnp.int32(0), jnp.float32(0.0),
             total == 0, jnp.bool_(False), jnp.maximum(first_target, 1.0))
    _, w, wq, _, ptf, _ = jax.lax.while_loop(cond, body, state)
    p_hat = wq / jnp.maximum(w.astype(jnp.float32), 1.0)
    est = total_f * p_hat
    return est, w, ptf


def estimate_one_table(view: TableView, qcode: jax.Array, qualfn: QualFn,
                       cfg: ProberConfig, key: jax.Array,
                       central_qualfn: QualFn | None = None):
    """Alg. 1: central bucket exactly, then rings k = 1..K adaptively.

    ``central_qualfn`` lets f_central stay exact (Alg. 3 is brute force —
    the paper applies ADC only inside f_neighbor) while rings use ADC.
    """
    ham = lsh.hamming_to_buckets(view.bucket_codes, view.n_buckets, qcode)
    est0, visited0 = _count_central(view, ham, central_qualfn or qualfn, cfg)
    n_rings = view.bucket_codes.shape[-1]  # max k = number of hash functions

    def cond(state):
        k, est, nvisited, ptf, key = state
        return (k <= n_rings) & (~ptf) & (nvisited < cfg.max_visit)

    def body(state):
        k, est, nvisited, ptf, key = state
        key, sub = jax.random.split(key)
        if central_qualfn is not None and cfg.pq_exact_rings > 0:
            # near rings carry the selectivity mass (paper Fig. 1): spend
            # exact distances there, ADC beyond (beyond-paper accuracy fix)
            ring_fn = lambda ids: jax.lax.cond(
                k <= cfg.pq_exact_rings, central_qualfn, qualfn, ids)
        else:
            ring_fn = qualfn
        ring_est, w, ring_ptf = _estimate_ring(view, ham == k, ring_fn, cfg, sub)
        return k + 1, est + ring_est, nvisited + w, ptf | ring_ptf, key

    state = (jnp.int32(1), est0, visited0, jnp.bool_(False), key)
    _, est, nvisited, _, _ = jax.lax.while_loop(cond, body, state)
    return est, nvisited


def make_exact_qualfn(x: jax.Array, q: jax.Array, tau_sq: jax.Array,
                      use_kernels: bool = False) -> QualFn:
    """Exact squared-L2 qualification (Def. 3): 1[d^2 <= tau^2]."""
    def fn(ids: jax.Array) -> jax.Array:
        rows = x[ids]                       # (c, d)
        if use_kernels:
            from repro.kernels import ops
            d2 = ops.l2dist(rows, q[None, :])[:, 0]
        else:
            diff = rows - q[None, :]
            d2 = jnp.sum(diff * diff, axis=-1)
        return (d2 <= tau_sq).astype(jnp.float32)
    return fn


def make_adc_qualfn(codes: jax.Array, lut: jax.Array, tau_sq: jax.Array,
                    resid: jax.Array | None = None,
                    banded: bool = False, use_kernels: bool = False) -> QualFn:
    """PQ-ADC qualification via the per-query LUT (Alg. 5).

    ``banded=False`` is the paper-faithful hard threshold on the ADC distance.
    ``banded=True`` (beyond-paper, DESIGN.md §3) uses the stored quantization
    residual r = ||p - q(p)||: by the triangle inequality the true distance
    lies in [max(0, adc - r), adc + r]; qualification weight is the fraction
    of that band below tau (linear CDF surrogate) — removes the systematic
    over/under-count when quantization distortion is comparable to tau.
    """
    m = lut.shape[0]
    marange = jnp.arange(m)
    tau = jnp.sqrt(tau_sq)

    def fn(ids: jax.Array) -> jax.Array:
        c = codes[ids]                      # (c, M)
        if use_kernels:
            from repro.kernels import ops
            adc_sq = ops.adc(c, lut)
        else:
            adc_sq = jnp.sum(lut[marange, c], axis=-1)
        if not banded or resid is None:
            return (adc_sq <= tau_sq).astype(jnp.float32)
        adc = jnp.sqrt(jnp.maximum(adc_sq, 0.0))
        r = resid[ids]
        lo = jnp.maximum(adc - r, 0.0)
        hi = adc + r
        w = jnp.where(hi > lo, (tau - lo) / jnp.maximum(hi - lo, 1e-12),
                      (adc <= tau).astype(jnp.float32))
        return jnp.clip(w, 0.0, 1.0)
    return fn


@partial(jax.jit, static_argnames=("cfg",))
def estimate(index: lsh.LSHIndex, x: jax.Array, q: jax.Array, tau: jax.Array,
             cfg: ProberConfig, key: jax.Array,
             pq_codes: jax.Array | None = None,
             pq_lut: jax.Array | None = None,
             pq_resid: jax.Array | None = None) -> jax.Array:
    """Estimate |{p : ||p - q|| <= tau}| for one query. Averages the
    per-table estimates over the L tables (each is unbiased for the full
    cardinality since every point lives in exactly one ring per table)."""
    tau_sq = jnp.asarray(tau, jnp.float32) ** 2
    qcodes = lsh.hash_point(index.params, q, index.n_tables)   # (L, K)
    views = table_views(index)
    if pq_codes is not None and pq_lut is not None:
        central_qualfn = make_exact_qualfn(x, q, tau_sq,   # Alg. 3: brute force
                                           use_kernels=cfg.use_kernels)
        qualfn = make_adc_qualfn(pq_codes, pq_lut, tau_sq, resid=pq_resid,
                                 banded=cfg.pq_banded,
                                 use_kernels=cfg.use_kernels)
    else:
        central_qualfn = None
        qualfn = make_exact_qualfn(x, q, tau_sq, use_kernels=cfg.use_kernels)
    keys = jax.random.split(key, index.n_tables)

    def per_table(view, qcode, k):
        est, _ = estimate_one_table(view, qcode, qualfn, cfg, k,
                                    central_qualfn=central_qualfn)
        return est

    ests = jax.vmap(per_table)(views, qcodes, keys)
    return jnp.mean(ests)
