"""Neighboring-based adaptive bucket probing (paper §4.3/4.4, Alg. 1–3).

TPU-native formulation (DESIGN.md §3): rings N_k are masks over the unique
bucket codes (``hamming == k``); ring candidates are gathered into a static
``ring_budget`` buffer via a cumsum/searchsorted inversion of the sorted-CSR
layout; progressive sampling walks a random permutation of that buffer in
fixed-size chunks inside ``lax.while_loop``, checking the Chernoff bounds of
§4.5 at the doubling schedule points ``s_{i+1} = 2 s_i``.

Everything is shape-static, jit-able and vmap-able over queries.
:func:`estimate` handles one query; :func:`estimate_batch` (DESIGN.md §9)
is the first-class multi-query path — the LSH hash of all Q queries is one
matmul, ring construction and progressive sampling are vmapped over queries
(each query keeps its own Chernoff stopping state inside the shared
``while_loop``), and the per-query PQ LUTs arrive pre-built as (Q, M, Kc).
"""
from __future__ import annotations

from functools import partial
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import lsh, sampling
from repro.core.config import ProberConfig

# qualfn(ids: (c,) int32) -> qualification weight in [0,1] per point
# (exact: 1[d^2 <= tau^2]; banded ADC: interpolated within the residual band)
QualFn = Callable[[jax.Array], jax.Array]


class TableView(NamedTuple):
    """One hash table's slice of the index (leading L axis stripped).

    Capacity padding (DESIGN.md §10) needs no extra plumbing here: dead
    point rows live in the sentinel bucket at row ``n_buckets``, and every
    ring op below masks the bucket axis by ``n_buckets`` (via
    ``hamming_to_buckets``'s K+1 distance), so rings, gathers and the
    central count only ever see live points.
    """
    order: jax.Array          # (N,)
    bucket_codes: jax.Array   # (B, K)
    bucket_starts: jax.Array  # (B,)
    bucket_sizes: jax.Array   # (B,)
    n_buckets: jax.Array      # ()


def table_views(index: lsh.LSHIndex) -> TableView:
    """Stacked (L, ...) view suitable for vmap over tables."""
    return TableView(index.order, index.bucket_codes, index.bucket_starts,
                     index.bucket_sizes, index.n_buckets)


def gather_ring_from_cum(view: TableView, cum: jax.Array, budget: int):
    """Gather up to ``budget`` point ids given a ring's size cumsum ``cum``.

    ``cum`` is ``cumsum(where(ring_mask, bucket_sizes, 0))`` — precomputed so
    the batched path can build every ring's cumsum in ONE op (DESIGN.md §9).
    Returns (ids (budget,), valid (budget,), total ()) where ``total`` is the
    *full* ring population |N_k| (may exceed budget).
    """
    total = cum[-1]
    slots = jnp.arange(budget, dtype=jnp.int32)
    j = jnp.searchsorted(cum, slots, side="right").astype(jnp.int32)
    j = jnp.minimum(j, cum.shape[0] - 1)
    prev = jnp.where(j > 0, cum[jnp.maximum(j - 1, 0)], 0)
    pos = view.bucket_starts[j] + (slots - prev)
    valid = slots < total
    pos = jnp.clip(jnp.where(valid, pos, 0), 0, view.order.shape[0] - 1)
    return view.order[pos], valid, total


def gather_ring(view: TableView, ring_mask: jax.Array, budget: int):
    """Gather up to ``budget`` point ids belonging to masked buckets."""
    sizes = jnp.where(ring_mask, view.bucket_sizes, 0)
    return gather_ring_from_cum(view, jnp.cumsum(sizes), budget)


def ring_cumsums(view: TableView, ham: jax.Array, n_rings: int) -> jax.Array:
    """Masked size cumsums for rings k = 0..n_rings in ONE batched op.

    Returns (n_rings+1, B); row k is ``cumsum(where(ham == k, sizes, 0))``,
    bit-identical to what :func:`gather_ring` would compute per ring — but
    hoisted out of the adaptive probing loop, where a fresh (B,) cumsum per
    visited ring dominated the profile (DESIGN.md §9).
    """
    ks = jnp.arange(n_rings + 1, dtype=jnp.int32)
    masks = ham[None, :] == ks[:, None]                      # (R, B)
    return jnp.cumsum(jnp.where(masks, view.bucket_sizes[None, :], 0), axis=-1)


def _prp_eval(idx: jax.Array, rks: jax.Array, mask: jax.Array,
              n_bits) -> jax.Array:
    """Keyed multiply/xorshift PRP on Z_{2^n}; ``mask = 2^n - 1``.

    Each round composes three bijections on Z_{2^n} (odd-multiplier product,
    xor with a right shift, keyed add), so the map is an exact permutation
    of [0, 2^n). ``n_bits``/``mask`` may be traced values — the progressive
    sampler evaluates the PRP over a per-ring power-of-two domain chosen at
    run time (DESIGN.md §9). Mixing is pseudo-random rather than uniformly
    distributed over S_n; accuracy envelopes are validated in
    tests/test_prober.py and benchmarks/bench_qerror.py.
    """
    x = idx.astype(jnp.uint32)
    mask = mask.astype(jnp.uint32) if hasattr(mask, "astype") else \
        jnp.uint32(mask)
    for i in range(3):
        x = (x * (rks[2 * i] | jnp.uint32(1))) & mask
        shift = n_bits // 2 + (i % 2) + 1
        x = x ^ jnp.right_shift(x, jnp.asarray(shift, jnp.uint32))
        x = (x + rks[2 * i + 1]) & mask
    return x.astype(jnp.int32)


def _count_central(view: TableView, cum0: jax.Array, qualfn: QualFn,
                   cfg: ProberConfig):
    """Alg. 3: exact brute-force count inside B_central.

    If the bucket exceeds ``central_budget`` the exact count over the gathered
    prefix is scaled by ``total/seen`` (static-shape cap; DESIGN.md §3).
    """
    ids, valid, total = gather_ring_from_cum(view, cum0, cfg.central_budget)
    qualified = jnp.sum(qualfn(ids) * valid)
    seen = jnp.sum(valid)
    scale = jnp.where(seen > 0, total / jnp.maximum(seen, 1), 0.0)
    return qualified * scale, seen


def estimate_one_table(view: TableView, qcode: jax.Array, qualfn: QualFn,
                       cfg: ProberConfig, key: jax.Array,
                       central_qualfn: QualFn | None = None,
                       exact_qualfn: QualFn | None = None,
                       axis_name=None):
    """Alg. 1: central bucket exactly, then rings k = 1..K adaptively.

    ``axis_name`` switches on the distributed *pooled-stopping* ("sync")
    mode (DESIGN.md §4): inside a shard_map over that mesh axis, the
    per-slab (w, w') Chernoff statistics are pooled with ONE small psum per
    ``while_loop`` iteration, so the ε-test of §4.5 sees the GLOBAL
    selectivity instead of each shard's local one. Every control decision
    (schedule anchors, ring advance, PTF, termination) is derived from the
    pooled values only, so all shards run the loop in lockstep — which is
    also what makes the in-loop collective legal. The returned estimate is
    the global one, identical (replicated) on every shard; ``nvisited``
    counts globally pooled samples, so the visit budget is scaled to
    ``cfg.max_visit`` × shards — max_visit keeps its per-shard meaning and
    the mesh spends the same total budget in both stopping modes.

    ``central_qualfn`` lets f_central stay exact (Alg. 3 is brute force —
    the paper applies ADC only inside f_neighbor) while rings use ADC;
    ``exact_qualfn`` independently routes near rings (k <= pq_exact_rings)
    through exact distances, so the pq_exact_central and pq_exact_rings
    knobs compose without coupling.

    Restructured for batching (DESIGN.md §9) into two phases:

    * **Ring construction** (loop-free): all rings' size cumsums come from
      ONE batched cumsum over the (trimmed) bucket axis; one shared
      pseudo-random permutation ``pi`` of the ring budget covers every ring.
      Nothing per-ring is materialised — so under a query batch this phase
      is a handful of fused, lockstep-free vector ops.
    * **Progressive sampling** (ONE flat ``while_loop``): each iteration
      evaluates one ``chunk``-sized slab of a keyed PRP over the current
      ring's own power-of-two domain P_k = next_pow2(cap_k), rejection-masks
      entries ``>= cap_k`` (the surviving subsequence of a permutation is a
      uniform random permutation of the ring's candidates, and P_k < 2 cap_k
      bounds the rejection rate below 1/2), resolves the slab's candidate
      ids through the ring cumsum on the fly, and carries a per-lane cursor
      ``(k, ci)`` plus the per-ring Chernoff state (Alg. 2) — folding the
      ring estimate and advancing ``k`` when the ring's stopping rule fires.
      Under vmap, total iterations = max over queries of the slabs that
      query actually needs — not (max rings) x (max chunks per ring), which
      is what the previous nested while_loops cost a batch — and each
      iteration is exactly the op-overhead-dominated work that batching
      amortises.
    """
    ham = lsh.hamming_to_buckets(view.bucket_codes, view.n_buckets, qcode)
    n_rings = view.bucket_codes.shape[-1]  # max k = number of hash functions
    n_buckets = view.bucket_sizes.shape[-1]
    cums = ring_cumsums(view, ham, n_rings)                    # (K+1, B)
    rks = jax.random.bits(key, (6,), jnp.uint32)   # PRP round keys, Alg. 2
    est0, visited0 = _count_central(view, cums[0], central_qualfn or qualfn,
                                    cfg)

    totals = cums[1:, -1]                                      # (K,) |N_k|
    totals_f = totals.astype(jnp.float32)
    caps = jnp.minimum(totals, cfg.ring_budget)
    # per-ring PRP domain: P_k = 2^{nbits_k} = next_pow2(cap_k)
    nbits = jnp.where(caps <= 1, 0,
                      32 - jax.lax.clz(jnp.maximum(caps - 1, 1)))
    prings = jnp.left_shift(1, nbits)                          # (K,)
    # schedule anchors per ring (Alg. 2 line 8): w_1 = ceil(s1 * |N_k|)
    w_caps = jnp.minimum(jnp.ceil(cfg.s_max * totals_f),
                         caps.astype(jnp.float32))
    totals_sched = totals_f
    visit_budget = jnp.int32(cfg.max_visit)
    if axis_name is not None:
        # pooled-stopping mode: the central count, schedule anchors and
        # sample caps become GLOBAL, so every stopping decision below is
        # shard-invariant (the PRP domains/caps above stay local — each
        # shard still samples only its own candidates). ``totals_f`` itself
        # stays LOCAL: each shard's ring estimate |N_k,s|·p̂_s is unbiased
        # under its own uniform sampling, and the psum of those is the
        # global ring count — pooling p̂ instead would overweight shards
        # that sample a larger fraction of their ring.
        est0 = jax.lax.psum(est0, axis_name)
        visited0 = jax.lax.psum(visited0, axis_name)
        totals_sched = jax.lax.psum(totals_f, axis_name)
        w_caps = jax.lax.psum(w_caps, axis_name)
        # nvisited pools globally here, so scale the visit budget by the
        # axis size — cfg.max_visit keeps its per-shard meaning and the
        # mesh gets the same total budget in both stopping modes
        visit_budget = visit_budget * jax.lax.psum(jnp.int32(1), axis_name)
    first_targets = jnp.maximum(jnp.ceil(cfg.s1 * totals_sched), 1.0)

    a = cfg.a_const
    chunk = cfg.chunk
    slot_iota = jnp.arange(chunk, dtype=jnp.int32)

    def cond(s):
        return ~s["done"]

    def body(s):
        k, ci, row = s["k"], s["ci"], s["k"] - 1
        p_ring = prings[row]
        idx = ci * chunk + slot_iota
        p_slab = _prp_eval(idx, rks, p_ring - 1, nbits[row])
        cum = cums[k]                                          # (B,)
        ok = (idx < p_ring) & (p_slab < caps[row])
        # resolve slab -> point ids through the ring's CSR cumsum
        j = jnp.minimum(jnp.searchsorted(cum, p_slab, side="right")
                        .astype(jnp.int32), n_buckets - 1)
        prev = jnp.where(j > 0, cum[jnp.maximum(j - 1, 0)], 0)
        pos = view.bucket_starts[j] + (p_slab - prev)
        pos = jnp.clip(jnp.where(ok, pos, 0), 0, view.order.shape[0] - 1)
        sl = view.order[pos]
        if exact_qualfn is not None and cfg.pq_exact_rings > 0:
            # near rings carry the selectivity mass (paper Fig. 1): spend
            # exact distances there, ADC beyond (beyond-paper accuracy fix)
            ring_fn = lambda ids: jax.lax.cond(
                k <= cfg.pq_exact_rings, exact_qualfn, qualfn, ids)
        else:
            ring_fn = qualfn
        wq = s["wq"] + jnp.sum(ring_fn(sl) * ok)
        w = s["w"] + jnp.sum(ok)
        exhausted = (ci + 1) * chunk >= p_ring     # local PRP domain walked
        # per-shard unbiased ring estimate |N_k|·p̂ (== the pooled one when
        # axis_name is None)
        ring_est = totals_f[row] * wq / jnp.maximum(w.astype(jnp.float32),
                                                    1.0)
        if axis_name is None:
            wf, wq_pool, all_exhausted = w.astype(jnp.float32), wq, exhausted
        else:
            # ONE small psum pools this slab's (w, w') Chernoff statistics,
            # the exhaustion vote and the weighted ring estimate; every
            # stopping quantity below derives from it, so the loop stays in
            # lockstep across shards
            pooled = jax.lax.psum(
                jnp.stack([w.astype(jnp.float32), wq,
                           exhausted.astype(jnp.float32), jnp.float32(1.0),
                           ring_est]),
                axis_name)
            wf, wq_pool = pooled[0], pooled[1]
            all_exhausted = pooled[2] >= pooled[3]
            ring_est = pooled[4]
        p_hat = wq_pool / jnp.maximum(wf, 1.0)
        w_cap = w_caps[row]
        at_schedule = (wf >= s["target"]) | (wf >= w_cap)
        if not cfg.schedule_checks:      # static: check bounds every chunk
            at_schedule = jnp.bool_(True)
        cond1 = sampling.stop_sampling(p_hat, wf, a, cfg.eps)
        cond2 = sampling.stop_probing(p_hat, wf, a, cfg.eps)
        ring_done = (at_schedule & (cond1 | cond2)) | (wf >= w_cap) | \
            all_exhausted
        ptf = s["ptf"] | (at_schedule & cond2)
        target = jnp.where(at_schedule, s["target"] * 2.0, s["target"])
        est = jnp.where(ring_done, s["est"] + ring_est, s["est"])
        nvisited = jnp.where(ring_done, s["nvisited"] + wf.astype(jnp.int32),
                             s["nvisited"])
        nk = jnp.where(ring_done, k + 1, k)
        nrow = jnp.minimum(nk - 1, n_rings - 1)
        return {
            "k": nk, "ci": jnp.where(ring_done, 0, ci + 1),
            "w": jnp.where(ring_done, 0, w),
            "wq": jnp.where(ring_done, 0.0, wq),
            "target": jnp.where(ring_done, first_targets[nrow], target),
            "est": est, "nvisited": nvisited, "ptf": ptf,
            "done": (nk > n_rings) | ptf | (nvisited >= visit_budget),
        }

    init = {"k": jnp.int32(1), "ci": jnp.int32(0), "w": jnp.int32(0),
            "wq": jnp.float32(0.0), "target": first_targets[0],
            "est": est0, "nvisited": visited0, "ptf": jnp.bool_(False),
            "done": jnp.bool_(n_rings < 1) | (visited0 >= visit_budget)}
    final = jax.lax.while_loop(cond, body, init)
    return final["est"], final["nvisited"]


def make_exact_qualfn(x: jax.Array, q: jax.Array, tau_sq: jax.Array,
                      use_kernels: bool = False) -> QualFn:
    """Exact squared-L2 qualification (Def. 3): 1[d^2 <= tau^2]."""
    def fn(ids: jax.Array) -> jax.Array:
        rows = x[ids]                       # (c, d)
        if use_kernels:
            from repro.kernels import ops
            d2 = ops.l2dist(rows, q[None, :])[:, 0]
        else:
            diff = rows - q[None, :]
            d2 = jnp.sum(diff * diff, axis=-1)
        return (d2 <= tau_sq).astype(jnp.float32)
    return fn


def make_adc_qualfn(codes: jax.Array, lut: jax.Array, tau_sq: jax.Array,
                    resid: jax.Array | None = None,
                    banded: bool = False, use_kernels: bool = False) -> QualFn:
    """PQ-ADC qualification via the per-query LUT (Alg. 5).

    ``banded=False`` is the paper-faithful hard threshold on the ADC distance.
    ``banded=True`` (beyond-paper, DESIGN.md §3) uses the stored quantization
    residual r = ||p - q(p)||: by the triangle inequality the true distance
    lies in [max(0, adc - r), adc + r]; qualification weight is the fraction
    of that band below tau (linear CDF surrogate) — removes the systematic
    over/under-count when quantization distortion is comparable to tau.
    """
    m = lut.shape[0]
    marange = jnp.arange(m)
    tau = jnp.sqrt(tau_sq)

    def fn(ids: jax.Array) -> jax.Array:
        c = codes[ids]                      # (c, M)
        if use_kernels:
            from repro.kernels import ops
            adc_sq = ops.adc(c, lut)
        else:
            adc_sq = jnp.sum(lut[marange, c], axis=-1)
        if not banded or resid is None:
            return (adc_sq <= tau_sq).astype(jnp.float32)
        adc = jnp.sqrt(jnp.maximum(adc_sq, 0.0))
        r = resid[ids]
        lo = jnp.maximum(adc - r, 0.0)
        hi = adc + r
        w = jnp.where(hi > lo, (tau - lo) / jnp.maximum(hi - lo, 1e-12),
                      (adc <= tau).astype(jnp.float32))
        return jnp.clip(w, 0.0, 1.0)
    return fn


def _make_qualfns(x: jax.Array, q: jax.Array, tau_sq: jax.Array,
                  cfg: ProberConfig, pq_codes, pq_lut, pq_resid):
    """Qualification routing shared by :func:`estimate` and
    :func:`estimate_batch` (keeping the two paths bit-identical).

    Returns (qualfn, central_qualfn, exact_qualfn): the ring distance
    function, the exact function for B_central (None = use ``qualfn``,
    the ``pq_exact_central=False`` serving trade), and the exact function
    for near rings k <= ``pq_exact_rings`` (None = ADC everywhere).
    """
    if pq_codes is not None and pq_lut is not None:
        qualfn = make_adc_qualfn(pq_codes, pq_lut, tau_sq, resid=pq_resid,
                                 banded=cfg.pq_banded,
                                 use_kernels=cfg.use_kernels)
        exact = make_exact_qualfn(x, q, tau_sq, use_kernels=cfg.use_kernels) \
            if (cfg.pq_exact_central or cfg.pq_exact_rings > 0) else None
        return (qualfn,
                exact if cfg.pq_exact_central else None,   # Alg. 3
                exact if cfg.pq_exact_rings > 0 else None)
    return (make_exact_qualfn(x, q, tau_sq, use_kernels=cfg.use_kernels),
            None, None)


@partial(jax.jit, static_argnames=("cfg",))
def estimate(index: lsh.LSHIndex, x: jax.Array, q: jax.Array, tau: jax.Array,
             cfg: ProberConfig, key: jax.Array,
             pq_codes: jax.Array | None = None,
             pq_lut: jax.Array | None = None,
             pq_resid: jax.Array | None = None) -> jax.Array:
    """Estimate |{p : ||p - q|| <= tau}| for one query. Averages the
    per-table estimates over the L tables (each is unbiased for the full
    cardinality since every point lives in exactly one ring per table)."""
    tau_sq = jnp.asarray(tau, jnp.float32) ** 2
    qcodes = lsh.hash_point(index.params, q, index.n_tables)   # (L, K)
    views = table_views(index)
    qualfn, central_qualfn, exact_qualfn = _make_qualfns(
        x, q, tau_sq, cfg, pq_codes, pq_lut, pq_resid)
    keys = jax.random.split(key, index.n_tables)

    def per_table(view, qcode, k):
        est, _ = estimate_one_table(view, qcode, qualfn, cfg, k,
                                    central_qualfn=central_qualfn,
                                    exact_qualfn=exact_qualfn)
        return est

    ests = jax.vmap(per_table)(views, qcodes, keys)
    return jnp.mean(ests)


@partial(jax.jit, static_argnames=("cfg", "axis_name"))
def estimate_batch(index: lsh.LSHIndex, x: jax.Array, qs: jax.Array,
                   taus: jax.Array, cfg: ProberConfig, keys: jax.Array,
                   pq_codes: jax.Array | None = None,
                   pq_luts: jax.Array | None = None,
                   pq_resid: jax.Array | None = None,
                   axis_name=None) -> jax.Array:
    """Batched Alg. 1–3: estimate Q cardinalities in one jitted step.

    ``qs`` is (Q, d), ``taus`` (Q,), ``keys`` (Q, 2) — one PRNG key per query
    so results are bit-identical to Q sequential :func:`estimate` calls with
    the same keys. The hash of all queries is a single (Q, d) @ (d, L·K)
    matmul; per-query ring masks, gathers and the progressive-sampling
    ``while_loop`` are vmapped, so each query carries its own Chernoff
    stopping state while the scan work is shared across the batch
    (DESIGN.md §9). ``pq_luts`` is the pre-built (Q, M, Kc) LUT stack.

    ``axis_name`` (sync mode, DESIGN.md §4): pool the Chernoff statistics
    across the shards of that mesh axis — see :func:`estimate_one_table`.
    The per-lane stopping flags are then shard-invariant, so the vmapped
    while_loop runs the same iteration count on every shard and the in-loop
    psum lines up.
    """
    qcodes = lsh.hash_point(index.params, qs, index.n_tables)   # (Q, L, K)
    views = table_views(index)
    use_pq = pq_codes is not None and pq_luts is not None

    def per_query(q, tau, qcode, key, lut):
        tau_sq = jnp.asarray(tau, jnp.float32) ** 2
        qualfn, central_qualfn, exact_qualfn = _make_qualfns(
            x, q, tau_sq, cfg, pq_codes if use_pq else None, lut, pq_resid)
        tkeys = jax.random.split(key, index.n_tables)

        def per_table(view, qc, k):
            est, _ = estimate_one_table(view, qc, qualfn, cfg, k,
                                        central_qualfn=central_qualfn,
                                        exact_qualfn=exact_qualfn,
                                        axis_name=axis_name)
            return est

        return jnp.mean(jax.vmap(per_table)(views, qcode, tkeys))

    if not use_pq:
        return jax.vmap(
            lambda q, t, qc, k: per_query(q, t, qc, k, None)
        )(qs, taus, qcodes, keys)
    return jax.vmap(per_query)(qs, taus, qcodes, keys, pq_luts)
