"""Neighboring-based adaptive bucket probing (paper §4.3/4.4, Alg. 1–3).

TPU-native formulation (DESIGN.md §3): rings N_k are masks over the unique
bucket codes (``hamming == k``); ring candidates are gathered into a static
``ring_budget`` buffer via a cumsum/searchsorted inversion of the sorted-CSR
layout; progressive sampling walks a random permutation of that buffer in
fixed-size chunks inside ``lax.while_loop``, checking the Chernoff bounds of
§4.5 at the doubling schedule points ``s_{i+1} = 2 s_i``.

Everything is shape-static, jit-able and vmap-able over queries.
:func:`estimate` handles one query; :func:`estimate_batch` (DESIGN.md §9)
is the first-class multi-query path — the LSH hash of all Q queries is one
matmul, ring construction and progressive sampling are vmapped over queries
(each query keeps its own Chernoff stopping state inside the shared
``while_loop``), and the per-query PQ LUTs arrive pre-built as (Q, M, Kc)
(or as a batched :class:`~repro.core.pq.QuantLUT` on the quantized ADC
datapath, DESIGN.md §11).

Skew resilience (DESIGN.md §11): with ``cfg.lane_block > 0`` (the default)
the batched path flattens the (Q, L) lane grid and periodically compacts
the still-active lanes into a dense prefix, so a few slow (query, table)
lanes no longer keep every finished lane's slab work alive — wall-clock
moves from max-lane toward mean-lane cost under skewed (tau, query) mixes
while staying bit-identical to the monolithic schedule.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import lsh, pq as pqmod, sampling
from repro.core.config import ProberConfig

# qualfn(ids: (c,) int32) -> qualification weight in [0,1] per point
# (exact: 1[d^2 <= tau^2]; banded ADC: interpolated within the residual band)
QualFn = Callable[[jax.Array], jax.Array]


class TableView(NamedTuple):
    """One hash table's slice of the index (leading L axis stripped).

    Capacity padding (DESIGN.md §10) needs no extra plumbing here: dead
    point rows live in the sentinel bucket at row ``n_buckets``, and every
    ring op below masks the bucket axis by ``n_buckets`` (via
    ``hamming_to_buckets``'s K+1 distance), so rings, gathers and the
    central count only ever see live points.
    """
    order: jax.Array          # (N,)
    bucket_codes: jax.Array   # (B, K)
    bucket_starts: jax.Array  # (B,)
    bucket_sizes: jax.Array   # (B,)
    n_buckets: jax.Array      # ()


def table_views(index: lsh.LSHIndex) -> TableView:
    """Stacked (L, ...) view suitable for vmap over tables."""
    return TableView(index.order, index.bucket_codes, index.bucket_starts,
                     index.bucket_sizes, index.n_buckets)


def gather_ring_from_cum(view: TableView, cum: jax.Array, budget: int):
    """Gather up to ``budget`` point ids given a ring's size cumsum ``cum``.

    ``cum`` is ``cumsum(where(ring_mask, bucket_sizes, 0))`` — precomputed so
    the batched path can build every ring's cumsum in ONE op (DESIGN.md §9).
    Returns (ids (budget,), valid (budget,), total ()) where ``total`` is the
    *full* ring population |N_k| (may exceed budget).
    """
    total = cum[-1]
    slots = jnp.arange(budget, dtype=jnp.int32)
    j = jnp.searchsorted(cum, slots, side="right").astype(jnp.int32)
    j = jnp.minimum(j, cum.shape[0] - 1)
    prev = jnp.where(j > 0, cum[jnp.maximum(j - 1, 0)], 0)
    pos = view.bucket_starts[j] + (slots - prev)
    valid = slots < total
    pos = jnp.clip(jnp.where(valid, pos, 0), 0, view.order.shape[0] - 1)
    return view.order[pos], valid, total


def gather_ring(view: TableView, ring_mask: jax.Array, budget: int):
    """Gather up to ``budget`` point ids belonging to masked buckets."""
    sizes = jnp.where(ring_mask, view.bucket_sizes, 0)
    return gather_ring_from_cum(view, jnp.cumsum(sizes), budget)


def ring_cumsums(view: TableView, ham: jax.Array, n_rings: int) -> jax.Array:
    """Masked size cumsums for rings k = 0..n_rings in ONE batched op.

    Returns (n_rings+1, B); row k is ``cumsum(where(ham == k, sizes, 0))``,
    bit-identical to what :func:`gather_ring` would compute per ring — but
    hoisted out of the adaptive probing loop, where a fresh (B,) cumsum per
    visited ring dominated the profile (DESIGN.md §9).
    """
    ks = jnp.arange(n_rings + 1, dtype=jnp.int32)
    masks = ham[None, :] == ks[:, None]                      # (R, B)
    return jnp.cumsum(jnp.where(masks, view.bucket_sizes[None, :], 0), axis=-1)


def _prp_eval(idx: jax.Array, rks: jax.Array, mask: jax.Array,
              n_bits) -> jax.Array:
    """Keyed multiply/xorshift PRP on Z_{2^n}; ``mask = 2^n - 1``.

    Each round composes three bijections on Z_{2^n} (odd-multiplier product,
    xor with a right shift, keyed add), so the map is an exact permutation
    of [0, 2^n). ``n_bits``/``mask`` may be traced values — the progressive
    sampler evaluates the PRP over a per-ring power-of-two domain chosen at
    run time (DESIGN.md §9). Mixing is pseudo-random rather than uniformly
    distributed over S_n; accuracy envelopes are validated in
    tests/test_prober.py and benchmarks/bench_qerror.py.
    """
    x = idx.astype(jnp.uint32)
    mask = mask.astype(jnp.uint32) if hasattr(mask, "astype") else \
        jnp.uint32(mask)
    for i in range(3):
        x = (x * (rks[2 * i] | jnp.uint32(1))) & mask
        shift = n_bits // 2 + (i % 2) + 1
        x = x ^ jnp.right_shift(x, jnp.asarray(shift, jnp.uint32))
        x = (x + rks[2 * i + 1]) & mask
    return x.astype(jnp.int32)


def _count_central(view: TableView, cum0: jax.Array, qualfn: QualFn,
                   cfg: ProberConfig):
    """Alg. 3: exact brute-force count inside B_central.

    If the bucket exceeds ``central_budget`` the exact count over the gathered
    prefix is scaled by ``total/seen`` (static-shape cap; DESIGN.md §3).
    """
    ids, valid, total = gather_ring_from_cum(view, cum0, cfg.central_budget)
    qualified = jnp.sum(qualfn(ids) * valid)
    seen = jnp.sum(valid)
    scale = jnp.where(seen > 0, total / jnp.maximum(seen, 1), 0.0)
    return qualified * scale, seen


class LaneCtx(NamedTuple):
    """Per-(query, table) loop constants of the progressive sampler.

    Built once per lane by :func:`_table_setup` (ring construction, Alg. 2's
    schedule anchors) and read-only inside the slab loop — which is what
    lets the compacting scheduler (DESIGN.md §11) gather just the active
    lanes' rows per tile instead of carrying them through the loop state.
    """
    cums: jax.Array            # (K+1, B) ring size cumsums (row k = ring k)
    rks: jax.Array             # (6,) PRP round keys (Alg. 2)
    prings: jax.Array          # (K,) per-ring PRP domain P_k = next_pow2(cap)
    caps: jax.Array            # (K,) per-ring sample caps min(|N_k|, budget)
    nbits: jax.Array           # (K,) log2(P_k)
    totals_f: jax.Array        # (K,) |N_k| (local shard counts)
    w_caps: jax.Array          # (K,) schedule cap ceil(s_max |N_k|)
    first_targets: jax.Array   # (K,) first anchor ceil(s1 |N_k|)
    visit_budget: jax.Array    # () int32 (scaled by shards in pooled mode)


def _table_setup(view: TableView, qcode: jax.Array, central_qualfn: QualFn,
                 cfg: ProberConfig, key: jax.Array):
    """Loop-free ring construction for one (query, table) lane (DESIGN.md
    §9): the batched Hamming compare, ONE cumsum covering every ring, the
    exact central count (Alg. 3) and the per-ring PRP domains / Chernoff
    schedule anchors. Returns ``(ctx, est0, visited0)``."""
    ham = lsh.hamming_to_buckets(view.bucket_codes, view.n_buckets, qcode)
    n_rings = view.bucket_codes.shape[-1]
    cums = ring_cumsums(view, ham, n_rings)                    # (K+1, B)
    rks = jax.random.bits(key, (6,), jnp.uint32)   # PRP round keys, Alg. 2
    est0, visited0 = _count_central(view, cums[0], central_qualfn, cfg)

    totals = cums[1:, -1]                                      # (K,) |N_k|
    totals_f = totals.astype(jnp.float32)
    caps = jnp.minimum(totals, cfg.ring_budget)
    # per-ring PRP domain: P_k = 2^{nbits_k} = next_pow2(cap_k)
    nbits = jnp.where(caps <= 1, 0,
                      32 - jax.lax.clz(jnp.maximum(caps - 1, 1)))
    prings = jnp.left_shift(1, nbits)                          # (K,)
    # schedule anchors per ring (Alg. 2 line 8): w_1 = ceil(s1 * |N_k|)
    w_caps = jnp.minimum(jnp.ceil(cfg.s_max * totals_f),
                         caps.astype(jnp.float32))
    first_targets = jnp.maximum(jnp.ceil(cfg.s1 * totals_f), 1.0)
    ctx = LaneCtx(cums=cums, rks=rks, prings=prings, caps=caps, nbits=nbits,
                  totals_f=totals_f, w_caps=w_caps,
                  first_targets=first_targets,
                  visit_budget=jnp.int32(cfg.max_visit))
    return ctx, est0, visited0


def _init_state(ctx: LaneCtx, est0, visited0, n_rings: int):
    return {"k": jnp.int32(1), "ci": jnp.int32(0), "w": jnp.int32(0),
            "wq": jnp.float32(0.0), "target": ctx.first_targets[0],
            "est": est0, "nvisited": visited0, "ptf": jnp.bool_(False),
            "done": jnp.bool_(n_rings < 1) | (visited0 >= ctx.visit_budget)}


def _make_ring_fn(qualfn: QualFn, exact_qualfn: QualFn | None,
                  cfg: ProberConfig):
    """Ring-indexed qualification dispatch shared by both schedulers: near
    rings k <= ``pq_exact_rings`` carry the selectivity mass (paper Fig. 1),
    so they may route through exact distances while farther rings use ADC
    (beyond-paper accuracy fix)."""
    if exact_qualfn is not None and cfg.pq_exact_rings > 0:
        return lambda k, ids: jax.lax.cond(
            k <= cfg.pq_exact_rings, exact_qualfn, qualfn, ids)
    return lambda k, ids: qualfn(ids)


def _slab_step(s, ctx: LaneCtx, get_cum, get_starts, get_order, ring_fn,
               cfg: ProberConfig, n_buckets: int, n_points: int,
               n_rings: int, axis_name=None):
    """One progressive-sampling slab (Alg. 2 body) for one lane.

    THE shared hot-loop body: the monolithic ``while_loop`` of
    :func:`estimate_one_table` and the compacting tiled scheduler of
    :func:`_estimate_batch_compact` both run exactly this function, which is
    what makes the two schedules bit-identical per lane (tested in
    tests/test_compact.py). ``get_cum``/``get_starts``/``get_order``
    abstract the index lookups (closure over one table's view vs. a
    lane-indexed gather into the stacked (L, ...) arrays); ``ring_fn(k,
    ids)`` is the per-ring qualification from :func:`_make_ring_fn`.

    Visit-budget check: the in-progress ring's (pooled) sample count ``wf``
    is folded into the budget test EVERY slab — ``nvisited`` alone only
    advances at ring completion, so checking it by itself could not fire
    mid-ring and overshot ``max_visit`` by up to a whole ring (bugfix, this
    PR). A budget hit forces ring completion, so the partial ring's
    (unbiased) estimate is still folded into the total.
    """
    chunk = cfg.chunk
    slot_iota = jnp.arange(chunk, dtype=jnp.int32)
    k, ci, row = s["k"], s["ci"], s["k"] - 1
    p_ring = ctx.prings[row]
    idx = ci * chunk + slot_iota
    p_slab = _prp_eval(idx, ctx.rks, p_ring - 1, ctx.nbits[row])
    cum = get_cum(k)                                           # (B,)
    ok = (idx < p_ring) & (p_slab < ctx.caps[row])
    # resolve slab -> point ids through the ring's CSR cumsum
    j = jnp.minimum(jnp.searchsorted(cum, p_slab, side="right")
                    .astype(jnp.int32), n_buckets - 1)
    prev = jnp.where(j > 0, cum[jnp.maximum(j - 1, 0)], 0)
    pos = get_starts(j) + (p_slab - prev)
    pos = jnp.clip(jnp.where(ok, pos, 0), 0, n_points - 1)
    sl = get_order(pos)
    wq = s["wq"] + jnp.sum(ring_fn(k, sl) * ok)
    w = s["w"] + jnp.sum(ok)
    exhausted = (ci + 1) * chunk >= p_ring     # local PRP domain walked
    # per-shard unbiased ring estimate |N_k|·p̂ (== the pooled one when
    # axis_name is None)
    ring_est = ctx.totals_f[row] * wq / jnp.maximum(w.astype(jnp.float32),
                                                    1.0)
    if axis_name is None:
        wf, wq_pool, all_exhausted = w.astype(jnp.float32), wq, exhausted
    else:
        # ONE small psum pools this slab's (w, w') Chernoff statistics,
        # the exhaustion vote and the weighted ring estimate; every
        # stopping quantity below derives from it, so the loop stays in
        # lockstep across shards
        pooled = jax.lax.psum(
            jnp.stack([w.astype(jnp.float32), wq,
                       exhausted.astype(jnp.float32), jnp.float32(1.0),
                       ring_est]),
            axis_name)
        wf, wq_pool = pooled[0], pooled[1]
        all_exhausted = pooled[2] >= pooled[3]
        ring_est = pooled[4]
    p_hat = wq_pool / jnp.maximum(wf, 1.0)
    w_cap = ctx.w_caps[row]
    at_schedule = (wf >= s["target"]) | (wf >= w_cap)
    if not cfg.schedule_checks:      # static: check bounds every chunk
        at_schedule = jnp.bool_(True)
    cond1 = sampling.stop_sampling(p_hat, wf, cfg.a_const, cfg.eps)
    cond2 = sampling.stop_probing(p_hat, wf, cfg.a_const, cfg.eps)
    budget_hit = (s["nvisited"] + wf.astype(jnp.int32)) >= ctx.visit_budget
    ring_done = (at_schedule & (cond1 | cond2)) | (wf >= w_cap) | \
        all_exhausted | budget_hit
    ptf = s["ptf"] | (at_schedule & cond2)
    target = jnp.where(at_schedule, s["target"] * 2.0, s["target"])
    est = jnp.where(ring_done, s["est"] + ring_est, s["est"])
    nvisited = jnp.where(ring_done, s["nvisited"] + wf.astype(jnp.int32),
                         s["nvisited"])
    nk = jnp.where(ring_done, k + 1, k)
    nrow = jnp.minimum(nk - 1, n_rings - 1)
    return {
        "k": nk, "ci": jnp.where(ring_done, 0, ci + 1),
        "w": jnp.where(ring_done, 0, w),
        "wq": jnp.where(ring_done, 0.0, wq),
        "target": jnp.where(ring_done, ctx.first_targets[nrow], target),
        "est": est, "nvisited": nvisited, "ptf": ptf,
        "done": (nk > n_rings) | ptf | budget_hit,
    }


def estimate_one_table(view: TableView, qcode: jax.Array, qualfn: QualFn,
                       cfg: ProberConfig, key: jax.Array,
                       central_qualfn: QualFn | None = None,
                       exact_qualfn: QualFn | None = None,
                       axis_name=None):
    """Alg. 1: central bucket exactly, then rings k = 1..K adaptively.

    ``axis_name`` switches on the distributed *pooled-stopping* ("sync")
    mode (DESIGN.md §4): inside a shard_map over that mesh axis, the
    per-slab (w, w') Chernoff statistics are pooled with ONE small psum per
    ``while_loop`` iteration, so the ε-test of §4.5 sees the GLOBAL
    selectivity instead of each shard's local one. Every control decision
    (schedule anchors, ring advance, PTF, termination) is derived from the
    pooled values only, so all shards run the loop in lockstep — which is
    also what makes the in-loop collective legal. The returned estimate is
    the global one, identical (replicated) on every shard; ``nvisited``
    counts globally pooled samples, so the visit budget is scaled to
    ``cfg.max_visit`` × shards — max_visit keeps its per-shard meaning and
    the mesh spends the same total budget in both stopping modes.

    ``central_qualfn`` lets f_central stay exact (Alg. 3 is brute force —
    the paper applies ADC only inside f_neighbor) while rings use ADC;
    ``exact_qualfn`` independently routes near rings (k <= pq_exact_rings)
    through exact distances, so the pq_exact_central and pq_exact_rings
    knobs compose without coupling.

    Restructured for batching (DESIGN.md §9) into two phases:

    * **Ring construction** (loop-free): all rings' size cumsums come from
      ONE batched cumsum over the (trimmed) bucket axis; one shared
      pseudo-random permutation ``pi`` of the ring budget covers every ring.
      Nothing per-ring is materialised — so under a query batch this phase
      is a handful of fused, lockstep-free vector ops.
    * **Progressive sampling** (ONE flat ``while_loop``): each iteration
      evaluates one ``chunk``-sized slab of a keyed PRP over the current
      ring's own power-of-two domain P_k = next_pow2(cap_k), rejection-masks
      entries ``>= cap_k`` (the surviving subsequence of a permutation is a
      uniform random permutation of the ring's candidates, and P_k < 2 cap_k
      bounds the rejection rate below 1/2), resolves the slab's candidate
      ids through the ring cumsum on the fly, and carries a per-lane cursor
      ``(k, ci)`` plus the per-ring Chernoff state (Alg. 2) — folding the
      ring estimate and advancing ``k`` when the ring's stopping rule fires.
      Under vmap, total iterations = max over queries of the slabs that
      query actually needs — not (max rings) x (max chunks per ring), which
      is what the previous nested while_loops cost a batch — and each
      iteration is exactly the op-overhead-dominated work that batching
      amortises.
    """
    final = _run_one_table(view, qcode, qualfn, cfg, key,
                           central_qualfn=central_qualfn,
                           exact_qualfn=exact_qualfn, axis_name=axis_name)
    return final["est"], final["nvisited"]


def _run_one_table(view: TableView, qcode: jax.Array, qualfn: QualFn,
                   cfg: ProberConfig, key: jax.Array,
                   central_qualfn: QualFn | None = None,
                   exact_qualfn: QualFn | None = None,
                   axis_name=None) -> dict:
    """The :func:`estimate_one_table` body, returning the loop's FINAL state
    dict instead of just (est, nvisited) — ``final["k"] - 1`` is the deepest
    ring the probe folded, which the estimate cache snapshots for its epoch
    invalidation check (DESIGN.md §12)."""
    n_rings = view.bucket_codes.shape[-1]  # max k = number of hash functions
    n_buckets = view.bucket_sizes.shape[-1]
    ctx, est0, visited0 = _table_setup(view, qcode, central_qualfn or qualfn,
                                       cfg, key)
    if axis_name is not None:
        # pooled-stopping mode: the central count, schedule anchors and
        # sample caps become GLOBAL, so every stopping decision below is
        # shard-invariant (the PRP domains/caps above stay local — each
        # shard still samples only its own candidates). ``totals_f`` itself
        # stays LOCAL: each shard's ring estimate |N_k,s|·p̂_s is unbiased
        # under its own uniform sampling, and the psum of those is the
        # global ring count — pooling p̂ instead would overweight shards
        # that sample a larger fraction of their ring.
        est0 = jax.lax.psum(est0, axis_name)
        visited0 = jax.lax.psum(visited0, axis_name)
        totals_sched = jax.lax.psum(ctx.totals_f, axis_name)
        # nvisited pools globally here, so scale the visit budget by the
        # axis size — cfg.max_visit keeps its per-shard meaning and the
        # mesh gets the same total budget in both stopping modes
        ctx = ctx._replace(
            w_caps=jax.lax.psum(ctx.w_caps, axis_name),
            first_targets=jnp.maximum(jnp.ceil(cfg.s1 * totals_sched), 1.0),
            visit_budget=ctx.visit_budget *
            jax.lax.psum(jnp.int32(1), axis_name))

    ring_fn = _make_ring_fn(qualfn, exact_qualfn, cfg)

    def body(s):
        return _slab_step(s, ctx, lambda k: ctx.cums[k],
                          lambda j: view.bucket_starts[j],
                          lambda pos: view.order[pos], ring_fn, cfg,
                          n_buckets, view.order.shape[0], n_rings,
                          axis_name=axis_name)

    init = _init_state(ctx, est0, visited0, n_rings)
    return jax.lax.while_loop(lambda s: ~s["done"], body, init)


def make_exact_qualfn(x: jax.Array, q: jax.Array, tau_sq: jax.Array,
                      use_kernels: bool = False) -> QualFn:
    """Exact squared-L2 qualification (Def. 3): 1[d^2 <= tau^2]."""
    def fn(ids: jax.Array) -> jax.Array:
        rows = x[ids]                       # (c, d)
        if use_kernels:
            from repro.kernels import ops
            d2 = ops.l2dist(rows, q[None, :])[:, 0]
        else:
            diff = rows - q[None, :]
            d2 = jnp.sum(diff * diff, axis=-1)
        return (d2 <= tau_sq).astype(jnp.float32)
    return fn


def _gather_codes(codes: jax.Array, packed: jax.Array | None,
                  ids: jax.Array) -> jax.Array:
    """Candidate code rows for ``ids`` — through the packed 4-bit matrix
    when available (half the gather bandwidth, DESIGN.md §11), else the
    byte codes. Both return identical integer code values."""
    if packed is not None:
        return pqmod.unpack_codes(packed[ids])
    return codes[ids]


def make_adc_qualfn(codes: jax.Array, lut: jax.Array, tau_sq: jax.Array,
                    resid: jax.Array | None = None,
                    banded: bool = False, use_kernels: bool = False,
                    packed: jax.Array | None = None) -> QualFn:
    """PQ-ADC qualification via the per-query LUT (Alg. 5).

    ``banded=False`` is the paper-faithful hard threshold on the ADC distance.
    ``banded=True`` (beyond-paper, DESIGN.md §3) uses the stored quantization
    residual r = ||p - q(p)||: by the triangle inequality the true distance
    lies in [max(0, adc - r), adc + r]; qualification weight is the fraction
    of that band below tau (linear CDF surrogate) — removes the systematic
    over/under-count when quantization distortion is comparable to tau.
    """
    m = lut.shape[0]
    marange = jnp.arange(m)
    tau = jnp.sqrt(tau_sq)

    def fn(ids: jax.Array) -> jax.Array:
        c = _gather_codes(codes, packed, ids)                  # (c, M)
        if use_kernels:
            from repro.kernels import ops
            adc_sq = ops.adc(c, lut)
        else:
            adc_sq = jnp.sum(lut[marange, c], axis=-1)
        if not banded or resid is None:
            return (adc_sq <= tau_sq).astype(jnp.float32)
        adc = jnp.sqrt(jnp.maximum(adc_sq, 0.0))
        r = resid[ids]
        lo = jnp.maximum(adc - r, 0.0)
        hi = adc + r
        w = jnp.where(hi > lo, (tau - lo) / jnp.maximum(hi - lo, 1e-12),
                      (adc <= tau).astype(jnp.float32))
        return jnp.clip(w, 0.0, 1.0)
    return fn


def make_adc_qualfn_q8(codes: jax.Array, qlut: "pqmod.QuantLUT",
                       tau_sq: jax.Array, use_kernels: bool = False,
                       packed: jax.Array | None = None) -> QualFn:
    """Quantized-domain ADC qualification (DESIGN.md §11).

    The per-candidate distance never leaves the integer domain: gather M
    uint8 LUT entries, accumulate in int32, and compare against
    ``pq.quantized_threshold`` — exact w.r.t. the dequantized distances, so
    the decision agrees with float32 ADC for every candidate whose float
    distance is farther than ``(M/2 + 1)·scale`` from ``tau²`` (the LUT
    rounding band; tests/test_quantized.py). The hot loop touches a
    uint8 LUT (4× smaller than float32) and — with ``packed`` — a 4-bit
    code matrix, which is the bandwidth the slab gathers are bound by.
    """
    m = qlut.q8.shape[0]
    marange = jnp.arange(m)
    thresh = pqmod.quantized_threshold(qlut, m, tau_sq)

    def fn(ids: jax.Array) -> jax.Array:
        c = _gather_codes(codes, packed, ids)                  # (c, M)
        if use_kernels:
            from repro.kernels import ops
            s = ops.adc_q8(c, qlut.q8)
        else:
            s = jnp.sum(qlut.q8[marange, c].astype(jnp.int32), axis=-1)
        return (s <= thresh).astype(jnp.float32)
    return fn


def _make_qualfns(x: jax.Array, q: jax.Array, tau_sq: jax.Array,
                  cfg: ProberConfig, pq_codes, pq_lut, pq_resid,
                  pq_packed=None):
    """Qualification routing shared by :func:`estimate` and
    :func:`estimate_batch` (keeping the two paths bit-identical).

    Returns (qualfn, central_qualfn, exact_qualfn): the ring distance
    function, the exact function for B_central (None = use ``qualfn``,
    the ``pq_exact_central=False`` serving trade), and the exact function
    for near rings k <= ``pq_exact_rings`` (None = ADC everywhere).
    ``pq_lut`` may be a float (M, Kc) table or a
    :class:`~repro.core.pq.QuantLUT` — the latter routes rings through the
    quantized integer datapath (DESIGN.md §11).
    """
    if pq_codes is not None and pq_lut is not None:
        if isinstance(pq_lut, pqmod.QuantLUT):
            qualfn = make_adc_qualfn_q8(pq_codes, pq_lut, tau_sq,
                                        use_kernels=cfg.use_kernels,
                                        packed=pq_packed)
        else:
            qualfn = make_adc_qualfn(pq_codes, pq_lut, tau_sq, resid=pq_resid,
                                     banded=cfg.pq_banded,
                                     use_kernels=cfg.use_kernels,
                                     packed=pq_packed)
        exact = make_exact_qualfn(x, q, tau_sq, use_kernels=cfg.use_kernels) \
            if (cfg.pq_exact_central or cfg.pq_exact_rings > 0) else None
        return (qualfn,
                exact if cfg.pq_exact_central else None,   # Alg. 3
                exact if cfg.pq_exact_rings > 0 else None)
    return (make_exact_qualfn(x, q, tau_sq, use_kernels=cfg.use_kernels),
            None, None)


@partial(jax.jit, static_argnames=("cfg",))
def estimate(index: lsh.LSHIndex, x: jax.Array, q: jax.Array, tau: jax.Array,
             cfg: ProberConfig, key: jax.Array,
             pq_codes: jax.Array | None = None,
             pq_lut: jax.Array | None = None,
             pq_resid: jax.Array | None = None,
             pq_packed: jax.Array | None = None) -> jax.Array:
    """Estimate |{p : ||p - q|| <= tau}| for one query. Averages the
    per-table estimates over the L tables (each is unbiased for the full
    cardinality since every point lives in exactly one ring per table)."""
    tau_sq = jnp.asarray(tau, jnp.float32) ** 2
    qcodes = lsh.hash_point(index.params, q, index.n_tables)   # (L, K)
    views = table_views(index)
    qualfn, central_qualfn, exact_qualfn = _make_qualfns(
        x, q, tau_sq, cfg, pq_codes, pq_lut, pq_resid, pq_packed=pq_packed)
    keys = jax.random.split(key, index.n_tables)

    def per_table(view, qcode, k):
        est, _ = estimate_one_table(view, qcode, qualfn, cfg, k,
                                    central_qualfn=central_qualfn,
                                    exact_qualfn=exact_qualfn)
        return est

    ests = jax.vmap(per_table)(views, qcodes, keys)
    return jnp.mean(ests)


def _estimate_batch_compact(index: lsh.LSHIndex, x: jax.Array, qs: jax.Array,
                            taus: jax.Array, cfg: ProberConfig,
                            keys: jax.Array, pq_codes=None, pq_luts=None,
                            pq_resid=None, pq_packed=None,
                            with_stats: bool = False):
    """Skew-resilient batched scheduler (DESIGN.md §11).

    The (Q, L) lane grid is flattened into one lane axis. Ring construction
    runs vmapped exactly like the monolithic path; the progressive-sampling
    loop is then driven by a compacting outer ``while_loop``:

    1. **Compact**: argsort the lane ``done`` mask (composed with the lane
       position for a deterministic, stability-independent order) so every
       still-active lane occupies a dense prefix; permute the small per-lane
       loop state alongside a lane-id permutation.
    2. **Tile**: run ``ceil(n_active / lane_tile)``-many fixed-size tiles —
       each gathers its lanes' :class:`LaneCtx` rows and runs
       ``cfg.lane_block`` slab iterations of the SAME :func:`_slab_step`
       body the monolithic loop uses (lanes finishing mid-block freeze via
       the same select masking `vmap`-of-`while_loop` applies).

    Finished lanes beyond the active prefix cost nothing, so total slab work
    tracks the SUM of per-lane slab counts (mean-lane) instead of
    ``n_lanes ×`` the slowest lane (max-lane) — the win under skewed
    (tau, query) mixes. Per-lane slab sequences, PRNG keys and reduction
    shapes are unchanged, so results are bit-identical to the monolithic
    schedule for every (lane_block, lane_tile) (tests/test_compact.py).

    Local-control only: every compaction decision derives from this
    process's own ``done`` flags, so the pooled-stopping ``sync`` mode
    (in-loop psum, DESIGN.md §4) keeps the monolithic lockstep loop —
    :func:`estimate_batch` routes ``axis_name`` calls there.
    """
    qcodes = lsh.hash_point(index.params, qs, index.n_tables)   # (Q, L, K)
    views = table_views(index)
    use_pq = pq_codes is not None and pq_luts is not None
    nq = qs.shape[0]
    nt = index.n_tables
    n_rings = views.bucket_codes.shape[-1]
    n_buckets = views.bucket_sizes.shape[-1]
    n_points = views.order.shape[-1]
    tau_sqs = jnp.asarray(taus, jnp.float32) ** 2

    # ---- per-lane ring construction (vmapped, loop-free; DESIGN.md §9) ----
    def setup_q(q, tau_sq, qcode_q, key, lut):
        qualfn, central_qualfn, _ = _make_qualfns(
            x, q, tau_sq, cfg, pq_codes if use_pq else None, lut, pq_resid,
            pq_packed=pq_packed)
        tkeys = jax.random.split(key, nt)
        return jax.vmap(
            lambda view, qc, k: _table_setup(view, qc,
                                             central_qualfn or qualfn,
                                             cfg, k)
        )(views, qcode_q, tkeys)

    if use_pq:
        ctx, est0, visited0 = jax.vmap(setup_q)(qs, tau_sqs, qcodes, keys,
                                                pq_luts)
    else:
        ctx, est0, visited0 = jax.vmap(
            lambda q, t, qc, k: setup_q(q, t, qc, k, None)
        )(qs, tau_sqs, qcodes, keys)

    # ---- flatten (Q, L) -> lanes, pad to a multiple of the tile size ----
    nl = nq * nt
    tile = max(min(cfg.lane_tile, nl), 1)
    nlp = -(-nl // tile) * tile

    def flat(a):
        a = a.reshape((nl,) + a.shape[2:])
        if nlp > nl:   # padding lanes replicate lane 0 (valid indices, done)
            a = jnp.concatenate(
                [a, jnp.broadcast_to(a[:1], (nlp - nl,) + a.shape[1:])],
                axis=0)
        return a

    ctx = jax.tree_util.tree_map(flat, ctx)
    est0, visited0 = flat(est0), flat(visited0)
    lane_q = flat(jnp.broadcast_to(
        jnp.arange(nq, dtype=jnp.int32)[:, None], (nq, nt)))
    lane_t = flat(jnp.broadcast_to(
        jnp.arange(nt, dtype=jnp.int32)[None, :], (nq, nt)))
    pad_lane = jnp.arange(nlp) >= nl
    state = {"k": jnp.full((nlp,), 1, jnp.int32),
             "ci": jnp.zeros((nlp,), jnp.int32),
             "w": jnp.zeros((nlp,), jnp.int32),
             "wq": jnp.zeros((nlp,), jnp.float32),
             "target": ctx.first_targets[:, 0],
             "est": est0, "nvisited": visited0,
             "ptf": jnp.zeros((nlp,), bool),
             "done": jnp.bool_(n_rings < 1) |
             (visited0 >= ctx.visit_budget) | pad_lane}

    # LaneCtx rows are gathered per tile; the (K+1, B) cumsums stay out of
    # the tile gather — each slab fetches only its lane's CURRENT ring row
    cums_all = ctx.cums
    small_ctx = ctx._replace(cums=None)
    block = max(cfg.lane_block, 1)

    def lane_step(s, lane, lctx, tid, q, tau_sq, lut):
        qualfn, _, exact_qualfn = _make_qualfns(
            x, q, tau_sq, cfg, pq_codes if use_pq else None, lut, pq_resid,
            pq_packed=pq_packed)
        ring_fn = _make_ring_fn(qualfn, exact_qualfn, cfg)
        return _slab_step(s, lctx, lambda k: cums_all[lane, k],
                          lambda j: views.bucket_starts[tid, j],
                          lambda pos: views.order[tid, pos], ring_fn, cfg,
                          n_buckets, n_points, n_rings)

    vstep = jax.vmap(lane_step)

    def outer_cond(c):
        return jnp.any(~c[1]["done"])

    def outer_body(c):
        perm0, st = c
        # deterministic compaction order: unique keys (done, position) make
        # the argsort independent of sort stability
        key_order = jnp.argsort(st["done"].astype(jnp.int32) * nlp +
                                jnp.arange(nlp, dtype=jnp.int32))
        perm = perm0[key_order]
        st = {kk: v[key_order] for kk, v in st.items()}
        n_active = jnp.sum(~st["done"]).astype(jnp.int32)
        n_tiles = (n_active + tile - 1) // tile

        def tile_work(t, stt):
            sl = t * tile
            s_t = {kk: jax.lax.dynamic_slice_in_dim(v, sl, tile)
                   for kk, v in stt.items()}
            lanes = jax.lax.dynamic_slice_in_dim(perm, sl, tile)
            lctx_t = jax.tree_util.tree_map(lambda a: a[lanes], small_ctx)
            qi, ti = lane_q[lanes], lane_t[lanes]
            q_t, tau_t = qs[qi], tau_sqs[qi]
            lut_t = jax.tree_util.tree_map(lambda a: a[qi], pq_luts) \
                if use_pq else None

            def one_slab(_, s_c):
                new = vstep(s_c, lanes, lctx_t, ti, q_t, tau_t, lut_t)
                return {kk: jnp.where(s_c["done"], s_c[kk], new[kk])
                        for kk in s_c}

            s_t = jax.lax.fori_loop(0, block, one_slab, s_t)
            return {kk: jax.lax.dynamic_update_slice_in_dim(
                stt[kk], s_t[kk], sl, 0) for kk in stt}

        st = jax.lax.fori_loop(0, n_tiles, tile_work, st)
        return (perm, st)

    perm, st = jax.lax.while_loop(outer_cond, outer_body,
                                  (jnp.arange(nlp, dtype=jnp.int32), state))

    def unperm(v, dtype):
        return jnp.zeros((nlp,), dtype).at[perm].set(v)[:nl].reshape(nq, nt)

    ests = unperm(st["est"], jnp.float32).mean(axis=1)
    if not with_stats:
        return ests
    probed_k = jnp.clip(unperm(st["k"], jnp.int32) - 1, 0, n_rings)
    nvis = unperm(st["nvisited"], jnp.int32).sum(axis=1)
    return ests, probed_k, nvis


@partial(jax.jit, static_argnames=("cfg", "axis_name", "with_stats"))
def estimate_batch(index: lsh.LSHIndex, x: jax.Array, qs: jax.Array,
                   taus: jax.Array, cfg: ProberConfig, keys: jax.Array,
                   pq_codes: jax.Array | None = None,
                   pq_luts: jax.Array | None = None,
                   pq_resid: jax.Array | None = None,
                   pq_packed: jax.Array | None = None,
                   axis_name=None, with_stats: bool = False):
    """Batched Alg. 1–3: estimate Q cardinalities in one jitted step.

    ``qs`` is (Q, d), ``taus`` (Q,), ``keys`` (Q, 2) — one PRNG key per query
    so results are bit-identical to Q sequential :func:`estimate` calls with
    the same keys. The hash of all queries is a single (Q, d) @ (d, L·K)
    matmul; per-query ring masks, gathers and the progressive-sampling
    ``while_loop`` are vmapped, so each query carries its own Chernoff
    stopping state while the scan work is shared across the batch
    (DESIGN.md §9). ``pq_luts`` is the pre-built (Q, M, Kc) LUT stack (or a
    batched :class:`~repro.core.pq.QuantLUT`, DESIGN.md §11).

    With ``cfg.lane_block > 0`` (default) and more lanes than one tile
    (``Q·L > cfg.lane_tile``) the loop runs under the skew-resilient
    compacting scheduler (:func:`_estimate_batch_compact`) — bit-identical
    results, mean-lane instead of max-lane wall-clock. A batch that fits
    one tile stays monolithic: compaction cannot retire work at sub-tile
    granularity, so it would be pure overhead there.

    ``axis_name`` (sync mode, DESIGN.md §4): pool the Chernoff statistics
    across the shards of that mesh axis — see :func:`estimate_one_table`.
    The per-lane stopping flags are then shard-invariant, so the vmapped
    while_loop runs the same iteration count on every shard and the in-loop
    psum lines up. Sync mode always uses the monolithic lockstep loop
    (compaction is local-control only — DESIGN.md §11).

    ``with_stats=True`` (static) additionally returns the per-(query,
    table) deepest folded ring ``probed_k`` (Q, L) and per-query pooled
    sample counts ``nvisited`` (Q,) — the provenance the estimate cache
    snapshots for its epoch-invalidation check (DESIGN.md §12). The
    estimates themselves are bit-identical with or without stats.
    """
    n_rings = index.codes.shape[-1]
    if axis_name is None and cfg.lane_block > 0 and \
            qs.shape[0] * index.n_tables > cfg.lane_tile:
        return _estimate_batch_compact(index, x, qs, taus, cfg, keys,
                                       pq_codes=pq_codes, pq_luts=pq_luts,
                                       pq_resid=pq_resid,
                                       pq_packed=pq_packed,
                                       with_stats=with_stats)
    qcodes = lsh.hash_point(index.params, qs, index.n_tables)   # (Q, L, K)
    views = table_views(index)
    use_pq = pq_codes is not None and pq_luts is not None

    def per_query(q, tau, qcode, key, lut):
        tau_sq = jnp.asarray(tau, jnp.float32) ** 2
        qualfn, central_qualfn, exact_qualfn = _make_qualfns(
            x, q, tau_sq, cfg, pq_codes if use_pq else None, lut, pq_resid,
            pq_packed=pq_packed)
        tkeys = jax.random.split(key, index.n_tables)

        def per_table(view, qc, k):
            final = _run_one_table(view, qc, qualfn, cfg, k,
                                   central_qualfn=central_qualfn,
                                   exact_qualfn=exact_qualfn,
                                   axis_name=axis_name)
            return final["est"], final["nvisited"], final["k"]

        ests, nvis, ks = jax.vmap(per_table)(views, qcode, tkeys)
        return (jnp.mean(ests), jnp.clip(ks - 1, 0, n_rings),
                jnp.sum(nvis))

    if not use_pq:
        ests, probed_k, nvis = jax.vmap(
            lambda q, t, qc, k: per_query(q, t, qc, k, None)
        )(qs, taus, qcodes, keys)
    else:
        ests, probed_k, nvis = jax.vmap(per_query)(qs, taus, qcodes, keys,
                                                   pq_luts)
    return (ests, probed_k, nvis) if with_stats else ests
