"""Dense GQA decoder-only transformer (qwen2 / qwen1.5 / qwen2.5 / olmo /
pixtral-backbone families).

Layer-stacked params consumed by ``lax.scan`` with ``jax.checkpoint`` around
the body (small HLO, remat-friendly). ``input_mode='embeds'`` (pixtral)
consumes precomputed frontend embeddings instead of token ids — the modality
frontend is a stub per the assignment spec.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.base import ModelConfig
from repro.sharding.act import constrain


def init_layer(key, cfg: ModelConfig):
    k1, k2 = jax.random.split(key)
    return {
        "attn": L.attn_init(k1, cfg),
        "mlp": L.mlp_init(k2, cfg),
        "ln1": L.norm_init(cfg, cfg.d_model),
        "ln2": L.norm_init(cfg, cfg.d_model),
    }


def init(key, cfg: ModelConfig):
    keys = jax.random.split(key, cfg.n_layers + 2)
    stacked = jax.vmap(lambda k: init_layer(k, cfg))(keys[:cfg.n_layers])
    return {
        "embed": L.embed_init(keys[-1], cfg),
        "layers": stacked,
        "final_norm": L.norm_init(cfg, cfg.d_model),
    }


def _attn(p, h, cfg):
    if cfg.chunked_attn:
        return L.chunked_causal_attention(p, h, cfg, block=cfg.attn_block)
    return L.causal_attention(p, h, cfg)


def _layer_fwd(p, x, cfg: ModelConfig):
    x = constrain(x)
    h = x + _attn(p["attn"], L.apply_norm(p["ln1"], x, cfg), cfg)
    h = constrain(h)
    h = h + L.apply_mlp(p["mlp"], L.apply_norm(p["ln2"], h, cfg), cfg)
    return constrain(h)


def backbone(params, x, cfg: ModelConfig):
    """x (B, S, D) activations -> (B, S, D) after all layers."""
    body = jax.checkpoint(lambda xx, lp: (_layer_fwd(lp, xx, cfg), None))
    x, _ = jax.lax.scan(body, x, params["layers"])
    return L.apply_norm(params["final_norm"], x, cfg)


def forward(params, batch, cfg: ModelConfig):
    """-> logits (B, S, V) f32."""
    if cfg.input_mode == "embeds":
        x = batch["embeds"].astype(L.cdtype(cfg))
    else:
        x = L.embed(params["embed"], batch["tokens"], cfg)
    x = backbone(params, constrain(x), cfg)
    return L.unembed(params["embed"], x, cfg)


def loss_fn(params, batch, cfg: ModelConfig):
    logits = forward(params, batch, cfg)
    return L.cross_entropy(logits[:, :-1], batch["labels"][:, 1:])


# ------------------------------------------------------------- serving -----

def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv, cfg.hd)
    if cfg.kv_quant:
        sshape = shape[:-1]
        return {"k": jnp.zeros(shape, jnp.int8),
                "v": jnp.zeros(shape, jnp.int8),
                "ks": jnp.ones(sshape, jnp.float32),
                "vs": jnp.ones(sshape, jnp.float32),
                "pos": jnp.zeros((), jnp.int32)}
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
            "pos": jnp.zeros((), jnp.int32)}


def decode_step(params, cache, tokens, cfg: ModelConfig):
    """One token for every sequence in the batch. tokens (B,) int32."""
    x = L.embed(params["embed"], tokens[:, None], cfg)     # (B, 1, D)
    pos = cache["pos"]

    if cfg.kv_quant:
        def body_q8(x, scanned):
            lp, ck, cv, ks, vs = scanned
            h = L.apply_norm(lp["ln1"], constrain(x), cfg)
            a, ck, cv, ks, vs = L.cached_decode_attention_q8(
                lp["attn"], h, ck, cv, ks, vs, pos, cfg)
            x = x + a
            x = x + L.apply_mlp(lp["mlp"], L.apply_norm(lp["ln2"], x, cfg), cfg)
            return constrain(x), (ck, cv, ks, vs)

        x, (nk, nv, nks, nvs) = jax.lax.scan(
            body_q8, x, (params["layers"], cache["k"], cache["v"],
                         cache["ks"], cache["vs"]))
        x = L.apply_norm(params["final_norm"], x, cfg)
        logits = L.unembed(params["embed"], x, cfg)[:, 0]
        return logits, {"k": nk, "v": nv, "ks": nks, "vs": nvs,
                        "pos": pos + 1}

    def body(x, scanned):
        lp, ck, cv = scanned
        h = L.apply_norm(lp["ln1"], constrain(x), cfg)
        a, nk, nv = L.cached_decode_attention(lp["attn"], h, ck, cv, pos, cfg)
        x = x + a
        x = x + L.apply_mlp(lp["mlp"], L.apply_norm(lp["ln2"], x, cfg), cfg)
        return constrain(x), (nk, nv)

    x, (nk, nv) = jax.lax.scan(body, x, (params["layers"], cache["k"], cache["v"]))
    x = L.apply_norm(params["final_norm"], x, cfg)
    logits = L.unembed(params["embed"], x, cfg)[:, 0]       # (B, V)
    return logits, {"k": nk, "v": nv, "pos": pos + 1}


def prefill(params, batch, cfg: ModelConfig, max_len: int | None = None,
            dtype=jnp.bfloat16):
    """Populate a KV cache from a full prompt; returns (cache, last_logits).

    Used by the serving engine; the dry-run prefill cells lower ``forward``.
    """
    if cfg.input_mode == "embeds":
        x = batch["embeds"].astype(L.cdtype(cfg))
    else:
        x = L.embed(params["embed"], batch["tokens"], cfg)
    b, s, _ = x.shape
    max_len = max_len or s
    positions = jnp.arange(s)[None, :]

    def body(x, lp):
        h = L.apply_norm(lp["ln1"], x, cfg)
        q, k, v = L.qkv_project(lp["attn"], h, cfg, positions)
        qpos = jnp.arange(s)
        mask = (qpos[:, None] >= qpos[None, :])[None, None]
        a = L._sdpa(q, k, v, mask, cfg) @ lp["attn"]["wo"].astype(x.dtype)
        x = x + a
        x = x + L.apply_mlp(lp["mlp"], L.apply_norm(lp["ln2"], x, cfg), cfg)
        return x, (k.astype(dtype), v.astype(dtype))

    x, (ks, vs) = jax.lax.scan(body, x, params["layers"])
    x = L.apply_norm(params["final_norm"], x, cfg)
    logits = L.unembed(params["embed"], x[:, -1:], cfg)[:, 0]
    pad = max_len - s
    ks = jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    vs = jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    cache = {"k": ks, "v": vs, "pos": jnp.asarray(s, jnp.int32)}
    return cache, logits
