"""Model-family registry: family name -> module with the uniform API
(init / forward / loss_fn / init_cache / decode_step)."""
from __future__ import annotations

from repro.models import moe, rglru, rwkv6, transformer, whisper
from repro.models.base import ModelConfig

FAMILIES = {
    "dense": transformer,
    "moe": moe,
    "rglru": rglru,
    "rwkv6": rwkv6,
    "whisper": whisper,
}


def get_family(cfg: ModelConfig):
    return FAMILIES[cfg.family]
