"""Model configuration shared by all assigned architecture families."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | rglru | rwkv6 | whisper
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: int = 0           # 0 -> d_model // n_heads
    qkv_bias: bool = False
    qk_norm: bool = False       # qwen3-style per-head RMSNorm on q/k
    norm: str = "rmsnorm"       # rmsnorm | layernorm | layernorm_nonparam
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # --- hybrid (RG-LRU) ---
    attn_every: int = 0         # 1 attention block per this many (0 = none)
    window: int = 0             # sliding-window size for local attention
    lru_width: int = 0
    conv_width: int = 4
    kv_quant: bool = False      # int8 KV cache for decode (dense family)
    chunked_attn: bool = False  # flash-style online-softmax attention for
                                # train/prefill (never materializes (S,S))
    attn_block: int = 512
    # --- rwkv ---
    rwkv_chunk: int = 128       # chunk-parallel WKV width (train/prefill)
    # --- enc-dec (whisper) ---
    enc_layers: int = 0
    dec_len: int = 448          # decoder length used for train shapes
    # --- input handling ---
    input_mode: str = "tokens"  # tokens | embeds (stub frontend) | encdec
    dtype: str = "bfloat16"     # activation/compute dtype

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def rwkv_heads(self) -> int:
        return self.d_model // 64   # RWKV6 uses fixed 64-dim heads

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def param_count(self) -> int:
        """Analytic parameter count (for roofline MODEL_FLOPS = 6·N·D)."""
        d, ff, v, l = self.d_model, self.d_ff, self.vocab, self.n_layers
        hd, h, kv = self.hd, self.n_heads, self.n_kv
        emb = v * d * (1 if self.tie_embeddings else 2)
        if self.family == "rwkv6":
            tm = 6 * d * d            # r,k,v,g,o,w projections (approx, incl. lora)
            cm = 2 * d * ff
            return emb + l * (tm + cm)
        attn = d * h * hd + 2 * d * kv * hd + h * hd * d
        if self.family == "moe":
            mlp = self.n_experts * 3 * d * ff
        else:
            mlp = 3 * d * ff
        if self.family == "rglru":
            g = self.n_layers // (self.attn_every or 3)
            rec_layers = l - g
            w = self.lru_width or d
            rec = 2 * d * w + w * d + 4 * w   # in/gate/out proj + lru params
            return emb + rec_layers * (rec + mlp) + g * (attn + mlp)
        if self.family == "whisper":
            enc = self.enc_layers * (attn + mlp)
            dec = l * (2 * attn + mlp)        # self + cross attention
            return emb + enc + dec
        return emb + l * (attn + mlp)

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k of n_experts)."""
        if self.family != "moe":
            return self.param_count()
        d, ff, v, l = self.d_model, self.d_ff, self.vocab, self.n_layers
        hd, h, kv = self.hd, self.n_heads, self.n_kv
        emb = v * d * (1 if self.tie_embeddings else 2)
        attn = d * h * hd + 2 * d * kv * hd + h * hd * d
        mlp = self.top_k * 3 * d * ff
        return emb + l * (attn + mlp)
