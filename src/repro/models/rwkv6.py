"""RWKV-6 "Finch" (rwkv6-1.6b): attention-free, data-dependent decay.

Time mixing maintains a per-head matrix state S (hd × hd):

    out_t = r_t · (S_{t-1} + diag(u) k_t v_tᵀ)
    S_t   = diag(w_t) S_{t-1} + k_t v_tᵀ

with data-dependent decay ``w_t = exp(-exp(w0 + tanh(x_w A_w) B_w))`` and
token-shift interpolation with LoRA-modulated mixing coefficients (ddlerp).
Heads are fixed at 64 channels (H = d_model / 64).

Train/prefill evaluates the recurrence with ``lax.scan`` over time inside a
``lax.scan`` over layers; decode is the O(1) single-step update — which is
what makes the ``long_500k`` (524288 context) cell runnable.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.models.base import ModelConfig
from repro.sharding.act import constrain

_LORA = 32     # lora rank for the ddlerp / decay modulators
_MIX = 5       # r, w, k, v, g


def _hd(cfg):   # rwkv head dim is fixed 64
    return 64


def tm_init(key, cfg: ModelConfig):
    d = cfg.d_model
    h = cfg.rwkv_heads
    ks = jax.random.split(key, 10)
    s = 1.0 / np.sqrt(d)
    return {
        "mu_x": jnp.full((d,), 0.5, jnp.float32),
        "mu": jnp.full((_MIX, d), 0.5, jnp.float32),
        "lora_a": jax.random.normal(ks[0], (d, _MIX * _LORA), jnp.float32) * s,
        "lora_b": jax.random.normal(ks[1], (_MIX, _LORA, d), jnp.float32) * 0.01,
        "w0": jnp.full((d,), -3.0, jnp.float32),
        "decay_a": jax.random.normal(ks[2], (d, _LORA), jnp.float32) * s,
        "decay_b": jax.random.normal(ks[3], (_LORA, d), jnp.float32) * 0.01,
        "u": jax.random.normal(ks[4], (d,), jnp.float32) * 0.1,
        "wr": jax.random.normal(ks[5], (d, d), jnp.float32) * s,
        "wk": jax.random.normal(ks[6], (d, d), jnp.float32) * s,
        "wv": jax.random.normal(ks[7], (d, d), jnp.float32) * s,
        "wg": jax.random.normal(ks[8], (d, d), jnp.float32) * s,
        "wo": jax.random.normal(ks[9], (d, d), jnp.float32) * s,
        "ln_scale": jnp.ones((d,), jnp.float32),   # group-norm over heads
    }


def _ddlerp(p, x, xx):
    """Data-dependent token-shift mix -> (x_r, x_w, x_k, x_v, x_g)."""
    base = x + (xx - x) * p["mu_x"].astype(x.dtype)
    lora = jnp.tanh(base @ p["lora_a"].astype(x.dtype))
    lora = lora.reshape(*lora.shape[:-1], _MIX, _LORA)
    delta = jnp.einsum("...mr,mrd->...md", lora.astype(jnp.float32), p["lora_b"])
    mix = p["mu"][None, None] + delta                       # (B, S, 5, D)
    return [x + (xx - x) * mix[..., i, :].astype(x.dtype) for i in range(_MIX)]


def _tm_projections(p, x, xx, cfg):
    """Shared by scan and step: project to r,k,v,g,w,u head tensors."""
    h, hd = cfg.rwkv_heads, _hd(cfg)
    xr, xw, xk, xv, xg = _ddlerp(p, x, xx)
    shape = (*x.shape[:-1], h, hd)
    r = (xr @ p["wr"].astype(x.dtype)).reshape(shape).astype(jnp.float32)
    k = (xk @ p["wk"].astype(x.dtype)).reshape(shape).astype(jnp.float32)
    v = (xv @ p["wv"].astype(x.dtype)).reshape(shape).astype(jnp.float32)
    g = jax.nn.silu(xg @ p["wg"].astype(x.dtype))
    dec = p["w0"] + jnp.tanh(xw.astype(jnp.float32) @ p["decay_a"]) @ p["decay_b"]
    w = jnp.exp(-jnp.exp(dec)).reshape(shape)               # (..., H, hd) f32
    return r, k, v, g, w


def _gn(p, o, cfg):
    """Per-head group norm on the wkv output (..., H, hd)."""
    mean = jnp.mean(o, axis=-1, keepdims=True)
    var = jnp.var(o, axis=-1, keepdims=True)
    o = (o - mean) * jax.lax.rsqrt(var + 1e-5)
    o = o.reshape(*o.shape[:-2], -1)
    return o * p["ln_scale"]


def _wkv_sequential(r, k, v, w, u):
    """Reference per-token recurrence. r/k/v/w (B, S, H, hd) f32."""
    b, s, h, hd = r.shape

    def step(S, xs):
        rt, kt, vt, wt = xs                                   # (B, H, hd) each
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
        out = jnp.einsum("bhk,bhkv->bhv", rt, S + u[None, :, :, None] * kv)
        S = wt[..., None] * S + kv
        return S, out

    S0 = jnp.zeros((b, h, hd, hd), jnp.float32)
    tmaj = tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, w))  # (S, B, H, hd)
    _, outs = jax.lax.scan(step, S0, tmaj)
    return jnp.moveaxis(outs, 0, 1)                           # (B, S, H, hd)


def _wkv_chunked(r, k, v, w, u, chunk: int):
    """Chunk-parallel WKV (TPU adaptation, DESIGN.md §3/§7).

    Within a chunk of length c the recurrence expands to a masked
    quasi-attention:   out_t = r̃_t·S_in + Σ_{s<t}(r̃_t·k̃_s) v_s + (r_t⊙u⊙k_t)·v_t
    with r̃_t = r_t ⊙ exp(cum_{t-1} - cum_mid), k̃_s = k_s ⊙ exp(cum_mid - cum_s)
    (cum = within-chunk cumulative log-decay; the mid-chunk shift bounds the
    exponents by half a chunk of decay). The sequential dependency collapses
    to a scan over S/c chunks carrying S — O(c²·hd) parallel math inside,
    MXU-friendly and ~c× fewer scan steps (this is what makes the train_4k
    cell compile: the 4096-step scan previously timed out SPMD partitioning).
    """
    b, s, h, hd = r.shape
    pad = (-s) % chunk
    if pad:
        zp = lambda t: jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = zp(r), zp(k), zp(v)
        w = jnp.pad(w, ((0, 0), (0, pad), (0, 0), (0, 0)),
                    constant_values=1.0)
    n = (s + pad) // chunk
    cshape = (b, n, chunk, h, hd)
    rc, kc, vc, wc = (t.reshape(cshape) for t in (r, k, v, w))
    logw = jnp.log(jnp.maximum(wc, 1e-38))
    cum = jnp.cumsum(logw, axis=2)                      # inclusive, (B,n,c,H,hd)
    cum_prev = cum - logw                               # exclusive (cum_{t-1})
    mid = cum[:, :, chunk // 2][:, :, None]
    r_t = rc * jnp.exp(cum_prev - mid)
    k_t = kc * jnp.exp(mid - cum)
    k_end = kc * jnp.exp(cum[:, :, -1:] - cum)          # for the state update
    mask = (jnp.arange(chunk)[:, None] > jnp.arange(chunk)[None, :])

    def body(S, xs):
        rt, kt, vt, rc_, kc_, vc_, ke, cend, cprev = xs
        # intra-chunk masked quasi-attention
        scores = jnp.einsum("bthk,bshk->bhts", rt, kt)
        scores = scores * mask[None, None]
        intra = jnp.einsum("bhts,bshv->bthv", scores, vc_)
        # current-token bonus
        bonus = jnp.einsum("bthk,hk,bthk->bth", rc_, u, kc_)
        intra = intra + bonus[..., None] * vc_
        # inter-chunk: carry-in state
        carry = jnp.einsum("bthk,bhkv->bthv", rc_ * jnp.exp(cprev), S)
        # state update
        S = jnp.exp(cend)[..., None] * S + jnp.einsum("bshk,bshv->bhkv", ke, vc_)
        return S, intra + carry

    S0 = jnp.zeros((b, h, hd, hd), jnp.float32)
    xs = tuple(jnp.moveaxis(t, 1, 0) for t in
               (r_t, k_t, vc, rc, kc, vc, k_end,
                cum[:, :, -1], cum_prev))
    _, outs = jax.lax.scan(body, S0, xs)
    o = jnp.moveaxis(outs, 0, 1).reshape(b, s + pad, h, hd)
    return o[:, :s]


def tm_fwd(p, x, cfg: ModelConfig):
    """Full-sequence time mixing. x (B, S, D)."""
    b, s, d = x.shape
    h, hd = cfg.rwkv_heads, _hd(cfg)
    xx = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]       # token shift
    r, k, v, g, w = _tm_projections(p, x, xx, cfg)
    u = p["u"].reshape(h, hd)
    if s > cfg.rwkv_chunk:
        o = _wkv_chunked(r, k, v, w, u, cfg.rwkv_chunk)
    else:
        o = _wkv_sequential(r, k, v, w, u)
    o = _gn(p, o, cfg).astype(x.dtype)
    return (o * g) @ p["wo"].astype(x.dtype)


def tm_step(p, x, state, cfg: ModelConfig):
    """Single token. x (B, D); state {"S": (B,H,hd,hd) f32, "shift": (B,D)}."""
    h, hd = cfg.rwkv_heads, _hd(cfg)
    x1 = x[:, None]
    xx = state["shift"][:, None].astype(x.dtype)
    r, k, v, g, w = _tm_projections(p, x1, xx, cfg)
    r, k, v, w = r[:, 0], k[:, 0], v[:, 0], w[:, 0]
    u = p["u"].reshape(h, hd)
    kv = jnp.einsum("bhk,bhv->bhkv", k, v)
    out = jnp.einsum("bhk,bhkv->bhv", r, state["S"] + u[None, :, :, None] * kv)
    S = w[..., None] * state["S"] + kv
    o = _gn(p, out[:, None], cfg).astype(x.dtype)
    o = (o * g) @ p["wo"].astype(x.dtype)
    return o[:, 0], {"S": S, "shift": x}


def cm_init(key, cfg: ModelConfig):
    d, f = cfg.d_model, cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "mu_k": jnp.full((d,), 0.5, jnp.float32),
        "mu_r": jnp.full((d,), 0.5, jnp.float32),
        "wk": jax.random.normal(k1, (d, f), jnp.float32) / np.sqrt(d),
        "wv": jax.random.normal(k2, (f, d), jnp.float32) / np.sqrt(f),
        "wr": jax.random.normal(k3, (d, d), jnp.float32) / np.sqrt(d),
    }


def cm_fwd(p, x, xx, cfg: ModelConfig):
    xk = x + (xx - x) * p["mu_k"].astype(x.dtype)
    xr = x + (xx - x) * p["mu_r"].astype(x.dtype)
    k = jnp.square(jax.nn.relu(xk @ p["wk"].astype(x.dtype)))
    return jax.nn.sigmoid(xr @ p["wr"].astype(x.dtype)) * (k @ p["wv"].astype(x.dtype))


def init_layer(key, cfg: ModelConfig):
    k1, k2 = jax.random.split(key)
    return {
        "tm": tm_init(k1, cfg), "cm": cm_init(k2, cfg),
        "ln1": L.norm_init(cfg, cfg.d_model),
        "ln2": L.norm_init(cfg, cfg.d_model),
    }


def init(key, cfg: ModelConfig):
    keys = jax.random.split(key, cfg.n_layers + 1)
    stacked = jax.vmap(lambda k: init_layer(k, cfg))(keys[:cfg.n_layers])
    return {
        "embed": L.embed_init(keys[-1], cfg),
        "layers": stacked,
        "final_norm": L.norm_init(cfg, cfg.d_model),
    }


def _layer_fwd(p, x, cfg: ModelConfig):
    x = constrain(x)
    x = x + tm_fwd(p["tm"], L.apply_norm(p["ln1"], x, cfg), cfg)
    x = constrain(x)
    h = L.apply_norm(p["ln2"], x, cfg)
    hh = jnp.pad(h, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    return constrain(x + cm_fwd(p["cm"], h, hh, cfg))


def forward(params, batch, cfg: ModelConfig):
    x = L.embed(params["embed"], batch["tokens"], cfg)
    body = jax.checkpoint(lambda xx, lp: (_layer_fwd(lp, xx, cfg), None))
    x, _ = jax.lax.scan(body, x, params["layers"])
    x = L.apply_norm(params["final_norm"], x, cfg)
    return L.unembed(params["embed"], x, cfg)


def loss_fn(params, batch, cfg: ModelConfig):
    logits = forward(params, batch, cfg)
    return L.cross_entropy(logits[:, :-1], batch["labels"][:, 1:])


# ------------------------------------------------------------- serving -----

def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    """O(1)-per-token state — independent of max_len (long_500k friendly)."""
    h, hd, d, l = cfg.rwkv_heads, 64, cfg.d_model, cfg.n_layers
    return {
        "S": jnp.zeros((l, batch, h, hd, hd), jnp.float32),
        "tm_shift": jnp.zeros((l, batch, d), dtype),
        "cm_shift": jnp.zeros((l, batch, d), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


def decode_step(params, cache, tokens, cfg: ModelConfig):
    x = L.embed(params["embed"], tokens[:, None], cfg)[:, 0]   # (B, D)

    def body(x, scanned):
        lp, S, tms, cms = scanned
        h = L.apply_norm(lp["ln1"], x[:, None], cfg)[:, 0]
        o, st = tm_step(lp["tm"], h, {"S": S, "shift": tms.astype(x.dtype)}, cfg)
        x = x + o
        h = L.apply_norm(lp["ln2"], x[:, None], cfg)[:, 0]
        o = cm_fwd(lp["cm"], h[:, None], cms[:, None].astype(x.dtype), cfg)[:, 0]
        x = x + o
        return x, (st["S"], st["shift"].astype(tms.dtype), h.astype(cms.dtype))

    x, (S, tms, cms) = jax.lax.scan(
        body, x, (params["layers"], cache["S"], cache["tm_shift"], cache["cm_shift"]))
    x = L.apply_norm(params["final_norm"], x[:, None], cfg)
    logits = L.unembed(params["embed"], x, cfg)[:, 0]
    return logits, {"S": S, "tm_shift": tms, "cm_shift": cms,
                    "pos": cache["pos"] + 1}
