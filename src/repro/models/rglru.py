"""RecurrentGemma / Griffin hybrid (recurrentgemma-9b): repeating
(recurrent, recurrent, local-attention) blocks with a GeGLU MLP after each
temporal-mixing block.

The RG-LRU linear recurrence ``h_t = a_t ⊙ h_{t-1} + sqrt(1-a_t²) ⊙ (i_t ⊙ x_t)``
is evaluated with ``lax.associative_scan`` (parallel prefix — O(log S) depth,
TPU friendly) for train/prefill and as a single-step update for decode. Local
attention uses the chunked sliding-window kernel from layers.py, so the whole
architecture is sub-quadratic and runs the ``long_500k`` cell.

Params: groups of 3 blocks stacked (G, ...) and a recurrent tail (for
n_layers % 3 != 0), both consumed via ``lax.scan``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.models.base import ModelConfig
from repro.sharding.act import constrain

_C = 8.0   # RG-LRU decay sharpness constant (Griffin)


def _w(cfg: ModelConfig) -> int:
    return cfg.lru_width or cfg.d_model


# ------------------------------------------------------- recurrent block ---

def rec_init(key, cfg: ModelConfig):
    d, w = cfg.d_model, _w(cfg)
    ks = jax.random.split(key, 6)
    s_d, s_w = 1.0 / np.sqrt(d), 1.0 / np.sqrt(w)
    return {
        "w_in": jax.random.normal(ks[0], (d, w), jnp.float32) * s_d,
        "w_gate": jax.random.normal(ks[1], (d, w), jnp.float32) * s_d,
        "w_out": jax.random.normal(ks[2], (w, d), jnp.float32) * s_w,
        "conv_w": jax.random.normal(ks[3], (cfg.conv_width, w), jnp.float32) * 0.1,
        "conv_b": jnp.zeros((w,), jnp.float32),
        "lru_lambda": jax.random.uniform(ks[4], (w,), jnp.float32, 0.1, 0.9),
        "w_a": jax.random.normal(ks[5], (w, w), jnp.float32) * s_w,
        "b_a": jnp.zeros((w,), jnp.float32),
        "w_x": jax.random.normal(ks[0], (w, w), jnp.float32) * s_w,
        "b_x": jnp.zeros((w,), jnp.float32),
    }


def _causal_conv(p, x):
    """Per-channel causal conv, width cw. x (B, S, W)."""
    cw = p["conv_w"].shape[0]
    out = jnp.zeros_like(x)
    for j in range(cw):
        shifted = jnp.pad(x, ((0, 0), (j, 0), (0, 0)))[:, :x.shape[1]]
        out = out + shifted * p["conv_w"][cw - 1 - j][None, None, :].astype(x.dtype)
    return out + p["conv_b"].astype(x.dtype)


def _lru_coeffs(p, u):
    """u (..., W) conv output -> (a, b) recurrence coefficients (f32)."""
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(uf @ p["w_a"] + p["b_a"])
    i = jax.nn.sigmoid(uf @ p["w_x"] + p["b_x"])
    log_a = -_C * jax.nn.softplus(p["lru_lambda"]) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * uf)
    return a, b


def rec_fwd(p, x, cfg: ModelConfig):
    """Full-sequence recurrent block. x (B, S, D) -> (B, S, D)."""
    u = _causal_conv(p, x @ p["w_in"].astype(x.dtype))
    a, b = _lru_coeffs(p, u)

    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, b1 * a2 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    gate = jax.nn.gelu(x @ p["w_gate"].astype(x.dtype))
    return (h.astype(x.dtype) * gate) @ p["w_out"].astype(x.dtype)


def rec_step(p, x, state, cfg: ModelConfig):
    """Single-token step. x (B, 1, D); state {h (B,W), conv (B,cw-1,W)}."""
    xi = x[:, 0] @ p["w_in"].astype(x.dtype)                  # (B, W)
    cw = cfg.conv_width
    hist = jnp.concatenate([state["conv"], xi[:, None]], axis=1)  # (B, cw, W)
    u = jnp.einsum("bcw,cw->bw", hist.astype(jnp.float32), p["conv_w"])
    u = u + p["conv_b"]
    a, b = _lru_coeffs(p, u)
    h = a * state["h"] + b                                    # (B, W)
    gate = jax.nn.gelu(x[:, 0] @ p["w_gate"].astype(x.dtype))
    out = (h.astype(x.dtype) * gate) @ p["w_out"].astype(x.dtype)
    new_state = {"h": h, "conv": hist[:, 1:].astype(state["conv"].dtype)}
    return out[:, None], new_state


# --------------------------------------------------------------- blocks ----

def _block_init(key, cfg: ModelConfig, kind: str):
    k1, k2 = jax.random.split(key)
    mix = rec_init(k1, cfg) if kind == "rec" else L.attn_init(k1, cfg)
    return {
        "mix": mix,
        "mlp": L.mlp_init(k2, cfg),
        "ln1": L.norm_init(cfg, cfg.d_model),
        "ln2": L.norm_init(cfg, cfg.d_model),
    }


def _block_fwd(p, x, cfg: ModelConfig, kind: str):
    x = constrain(x)
    h = L.apply_norm(p["ln1"], x, cfg)
    if kind == "rec":
        x = x + rec_fwd(p["mix"], h, cfg)
    else:
        x = x + L.windowed_attention(p["mix"], h, cfg)
    x = x + L.apply_mlp(p["mlp"], L.apply_norm(p["ln2"], x, cfg), cfg)
    return constrain(x)


def n_groups(cfg: ModelConfig) -> tuple[int, int]:
    per = cfg.attn_every or 3
    return cfg.n_layers // per, cfg.n_layers % per


def init(key, cfg: ModelConfig):
    g, tail = n_groups(cfg)
    keys = jax.random.split(key, 2)
    gks = jax.random.split(keys[0], g)

    def group_init(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {"rec1": _block_init(k1, cfg, "rec"),
                "rec2": _block_init(k2, cfg, "rec"),
                "attn": _block_init(k3, cfg, "attn")}

    params = {
        "embed": L.embed_init(keys[1], cfg),
        "groups": jax.vmap(group_init)(gks),
        "final_norm": L.norm_init(cfg, cfg.d_model),
    }
    if tail:
        tks = jax.random.split(keys[0], tail)
        params["tail"] = jax.vmap(lambda k: _block_init(k, cfg, "rec"))(tks)
    return params


def forward(params, batch, cfg: ModelConfig):
    x = L.embed(params["embed"], batch["tokens"], cfg)

    def group_fwd(xx, gp):
        xx = _block_fwd(gp["rec1"], xx, cfg, "rec")
        xx = _block_fwd(gp["rec2"], xx, cfg, "rec")
        xx = _block_fwd(gp["attn"], xx, cfg, "attn")
        return xx, None

    x, _ = jax.lax.scan(jax.checkpoint(group_fwd), x, params["groups"])
    if "tail" in params:
        body = jax.checkpoint(lambda xx, lp: (_block_fwd(lp, xx, cfg, "rec"), None))
        x, _ = jax.lax.scan(body, x, params["tail"])
    x = L.apply_norm(params["final_norm"], x, cfg)
    return L.unembed(params["embed"], x, cfg)


def loss_fn(params, batch, cfg: ModelConfig):
    logits = forward(params, batch, cfg)
    return L.cross_entropy(logits[:, :-1], batch["labels"][:, 1:])


# ------------------------------------------------------------- serving -----

def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    """Recurrent state + ring-buffer attention caches.

    ``max_len`` bounds only decode position bookkeeping — the attention cache
    is the window size, so memory is O(window), not O(max_len): this is what
    makes ``long_500k`` (524288-token context) runnable.
    """
    g, tail = n_groups(cfg)
    w = _w(cfg)
    win = min(cfg.window or max_len, max_len)

    def rec_state(n):
        return {"h": jnp.zeros((n, batch, w), jnp.float32),
                "conv": jnp.zeros((n, batch, cfg.conv_width - 1, w), dtype)}

    cache = {
        "rec1": rec_state(g), "rec2": rec_state(g),
        "k": jnp.zeros((g, batch, win, cfg.n_kv, cfg.hd), dtype),
        "v": jnp.zeros((g, batch, win, cfg.n_kv, cfg.hd), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }
    if tail:
        cache["tail"] = rec_state(tail)
    return cache


def decode_step(params, cache, tokens, cfg: ModelConfig):
    x = L.embed(params["embed"], tokens[:, None], cfg)
    pos = cache["pos"]

    def group_step(x, scanned):
        gp, st1, st2, ck, cv = scanned
        h = L.apply_norm(gp["rec1"]["ln1"], x, cfg)
        o, st1 = rec_step(gp["rec1"]["mix"], h, st1, cfg)
        x = x + o
        x = x + L.apply_mlp(gp["rec1"]["mlp"], L.apply_norm(gp["rec1"]["ln2"], x, cfg), cfg)
        h = L.apply_norm(gp["rec2"]["ln1"], x, cfg)
        o, st2 = rec_step(gp["rec2"]["mix"], h, st2, cfg)
        x = x + o
        x = x + L.apply_mlp(gp["rec2"]["mlp"], L.apply_norm(gp["rec2"]["ln2"], x, cfg), cfg)
        h = L.apply_norm(gp["attn"]["ln1"], x, cfg)
        a, nk, nv = L.cached_decode_attention(gp["attn"]["mix"], h, ck, cv, pos, cfg)
        x = x + a
        x = x + L.apply_mlp(gp["attn"]["mlp"], L.apply_norm(gp["attn"]["ln2"], x, cfg), cfg)
        return x, (st1, st2, nk, nv)

    x, (st1, st2, nk, nv) = jax.lax.scan(
        group_step, x,
        (params["groups"], cache["rec1"], cache["rec2"], cache["k"], cache["v"]))
    new_cache = dict(cache, rec1=st1, rec2=st2, k=nk, v=nv, pos=pos + 1)
    if "tail" in params:
        def tail_step(x, scanned):
            lp, st = scanned
            h = L.apply_norm(lp["ln1"], x, cfg)
            o, st = rec_step(lp["mix"], h, st, cfg)
            x = x + o
            x = x + L.apply_mlp(lp["mlp"], L.apply_norm(lp["ln2"], x, cfg), cfg)
            return x, st
        x, st = jax.lax.scan(tail_step, x, (params["tail"], cache["tail"]))
        new_cache["tail"] = st
    x = L.apply_norm(params["final_norm"], x, cfg)
    logits = L.unembed(params["embed"], x, cfg)[:, 0]
    return logits, new_cache
