"""Shared pure-JAX layers: norms, RoPE, GQA attention (full / windowed /
cached), SwiGLU MLP, embeddings.

Conventions:
  * params are nested dicts of jnp arrays; layer-stacked weights carry a
    leading L axis and are consumed via ``lax.scan``.
  * compute dtype is cfg.dtype (bf16); params and reductions stay f32.
  * attention uses chunked sliding-window when ``window`` is set — exact for
    window <= chunk and sub-quadratic in sequence length.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.base import ModelConfig


def cdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------- norms ----

def norm_init(cfg: ModelConfig, dim: int):
    if cfg.norm == "layernorm_nonparam":
        return {}
    p = {"scale": jnp.ones((dim,), jnp.float32)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((dim,), jnp.float32)
    return p


def apply_norm(p, x, cfg: ModelConfig, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if cfg.norm == "rmsnorm":
        xf = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
        return (xf * p["scale"]).astype(x.dtype)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    xf = (xf - mean) * jax.lax.rsqrt(var + eps)
    if cfg.norm == "layernorm_nonparam":     # OLMo: non-parametric LN
        return xf.astype(x.dtype)
    return (xf * p["scale"] + p["bias"]).astype(x.dtype)


# ----------------------------------------------------------------- rope ----

def rope_freqs(cfg: ModelConfig, positions: jax.Array) -> tuple[jax.Array, jax.Array]:
    """positions (...,) -> cos/sin of shape (..., hd/2)."""
    hd = cfg.hd
    inv = 1.0 / (cfg.rope_theta ** (np.arange(0, hd, 2) / hd))
    ang = positions[..., None].astype(jnp.float32) * inv[None, :]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x (..., S, H, hd); cos/sin (..., S, hd/2) broadcast over heads."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    c = cos[..., None, :]
    s = sin[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s],
                           axis=-1).astype(x.dtype)


# ------------------------------------------------------------ attention ----

def attn_init(key, cfg: ModelConfig, dim: Optional[int] = None):
    d = dim or cfg.d_model
    h, kv, hd = cfg.n_heads, cfg.n_kv, cfg.hd
    k1, k2, k3, k4 = jax.random.split(key, 4)
    scale = 1.0 / np.sqrt(d)
    p = {
        "wq": jax.random.normal(k1, (d, h * hd), jnp.float32) * scale,
        "wk": jax.random.normal(k2, (d, kv * hd), jnp.float32) * scale,
        "wv": jax.random.normal(k3, (d, kv * hd), jnp.float32) * scale,
        "wo": jax.random.normal(k4, (h * hd, d), jnp.float32) * scale,
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), jnp.float32)
        p["bk"] = jnp.zeros((kv * hd,), jnp.float32)
        p["bv"] = jnp.zeros((kv * hd,), jnp.float32)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), jnp.float32)
        p["k_norm"] = jnp.ones((hd,), jnp.float32)
    return p


def _qk_rmsnorm(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    xf = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (xf * scale).astype(x.dtype)


def qkv_project(p, x, cfg: ModelConfig, positions: jax.Array):
    """x (B, S, D) -> q (B, S, H, hd), k/v (B, S, KV, hd) with RoPE applied."""
    b, s, _ = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv, cfg.hd
    q = x @ p["wq"].astype(x.dtype)
    k = x @ p["wk"].astype(x.dtype)
    v = x @ p["wv"].astype(x.dtype)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = q.reshape(b, s, h, hd)
    k = k.reshape(b, s, kv, hd)
    v = v.reshape(b, s, kv, hd)
    if cfg.qk_norm:
        q = _qk_rmsnorm(q, p["q_norm"])
        k = _qk_rmsnorm(k, p["k_norm"])
    if cfg.rope_theta > 0:
        cos, sin = rope_freqs(cfg, positions)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    return q, k, v


def _sdpa(q, k, v, mask, cfg: ModelConfig):
    """q (B,Sq,H,hd), k/v (B,Sk,KV,hd), mask broadcastable (B,1,Sq,Sk).

    Grouped-query form: q is reshaped to (B,Sq,KV,rep,hd) and contracted
    against the UN-repeated K/V — ``jnp.repeat`` materialized rep× copies of
    the cache and forced full-cache all-gathers under SPMD (2×13.4 GiB/layer
    measured on qwen2-7b decode; EXPERIMENTS.md §Perf iteration 2).
    """
    h, kv = cfg.n_heads, cfg.n_kv
    rep = h // kv
    b, sq = q.shape[:2]
    qg = q.reshape(b, sq, kv, rep, cfg.hd)
    scores = jnp.einsum("bqgrd,bkgd->bgrqk", qg, k).astype(jnp.float32)
    scores = scores / np.sqrt(cfg.hd)
    scores = jnp.where(mask[:, :, None], scores, -1e30)   # (B,g,r,Sq,Sk)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bgrqk,bkgd->bqgrd", probs, v)
    return out.reshape(b, sq, h * cfg.hd)


def causal_attention(p, x, cfg: ModelConfig, positions=None, causal=True):
    """Full (quadratic) attention over x (B, S, D)."""
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s)[None, :]
    q, k, v = qkv_project(p, x, cfg, positions)
    qpos = jnp.arange(s)
    if causal:
        mask = (qpos[:, None] >= qpos[None, :])[None, None]
    else:
        mask = jnp.ones((1, 1, s, s), bool)
    out = _sdpa(q, k, v, mask, cfg)
    return out @ p["wo"].astype(x.dtype)


def chunked_causal_attention(p, x, cfg: ModelConfig, positions=None,
                             block: int = 512):
    """Flash-style causal attention: online softmax over KV blocks.

    Never materializes the (S, S) score matrix — scores exist one
    (B, S, H, block) tile at a time inside a ``lax.scan`` over KV blocks
    (with an early full-skip mask for blocks entirely in the causal
    future). Enabled per-config with ``chunked_attn`` (§Perf addendum).
    """
    b, s, _ = x.shape
    if s <= block:
        return causal_attention(p, x, cfg, positions)
    if positions is None:
        positions = jnp.arange(s)[None, :]
    q, k, v = qkv_project(p, x, cfg, positions)
    h, kv, hd = cfg.n_heads, cfg.n_kv, cfg.hd
    rep = h // kv
    pad = (-s) % block
    sp = s + pad
    kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nblk = sp // block
    kb = jnp.moveaxis(kp.reshape(b, nblk, block, kv, hd), 1, 0)
    vb = jnp.moveaxis(vp.reshape(b, nblk, block, kv, hd), 1, 0)
    qg = q.reshape(b, s, kv, rep, hd)
    qpos = jnp.arange(s)
    scale = 1.0 / np.sqrt(hd)

    def body(carry, xs):
        m, l, acc = carry                       # (B,S,KV,rep) ×2, (…,hd)
        kblk, vblk, bidx = xs
        kpos = bidx * block + jnp.arange(block)
        mask = (qpos[:, None] >= kpos[None, :])          # (S, block)
        sc = jnp.einsum("bqgrd,bkgd->bqgrk", qg, kblk).astype(jnp.float32)
        sc = sc * scale
        sc = jnp.where(mask[None, :, None, None, :], sc, -1e30)
        m_new = jnp.maximum(m, jnp.max(sc, axis=-1))
        p_blk = jnp.exp(sc - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p_blk, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bqgrk,bkgd->bqgrd", p_blk.astype(qg.dtype), vblk).astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, s, kv, rep), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, s, kv, rep), jnp.float32)
    a0 = jnp.zeros((b, s, kv, rep, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (kb, vb, jnp.arange(nblk)))
    out = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(x.dtype)
    out = out.reshape(b, s, h * hd)
    return out @ p["wo"].astype(x.dtype)


def windowed_attention(p, x, cfg: ModelConfig, positions=None):
    """Chunked sliding-window attention, exact for window <= chunk.

    S is padded to a multiple of W; each chunk attends to itself and the
    previous chunk under the combined causal+window mask. Memory/compute is
    O(S · 2W) instead of O(S²).
    """
    w = cfg.window
    b, s, d = x.shape
    if s <= w:   # small sequences: plain causal attention
        return causal_attention(p, x, cfg, positions)
    if positions is None:
        positions = jnp.arange(s)[None, :]
    q, k, v = qkv_project(p, x, cfg, positions)
    pad = (-s) % w
    sp = s + pad
    nchunk = sp // w

    def pad_t(t):
        return jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))

    qc = pad_t(q).reshape(b, nchunk, w, cfg.n_heads, cfg.hd)
    kc = pad_t(k).reshape(b, nchunk, w, cfg.n_kv, cfg.hd)
    vc = pad_t(v).reshape(b, nchunk, w, cfg.n_kv, cfg.hd)
    # keys for chunk i = chunks [i-1, i]
    k_prev = jnp.concatenate([jnp.zeros_like(kc[:, :1]), kc[:, :-1]], axis=1)
    v_prev = jnp.concatenate([jnp.zeros_like(vc[:, :1]), vc[:, :-1]], axis=1)
    kk = jnp.concatenate([k_prev, kc], axis=2)       # (B, C, 2W, KV, hd)
    vv = jnp.concatenate([v_prev, vc], axis=2)
    qpos = jnp.arange(w)                             # within-chunk query pos
    kpos = jnp.arange(2 * w) - w                     # relative key pos
    rel = qpos[:, None] - kpos[None, :]              # how far back key is
    mask = (rel >= 0) & (rel < w)                    # causal + window
    first_chunk_mask = kpos[None, :] >= 0            # chunk 0 has no prev
    cm = jnp.broadcast_to(mask, (nchunk, w, 2 * w))
    cm = cm.at[0].set(mask & first_chunk_mask)
    h, kv = cfg.n_heads, cfg.n_kv
    rep = h // kv
    if rep > 1:
        kk = jnp.repeat(kk, rep, axis=3)
        vv = jnp.repeat(vv, rep, axis=3)
    scores = jnp.einsum("bcqhd,bckhd->bchqk", qc, kk).astype(jnp.float32)
    scores = scores / np.sqrt(cfg.hd)
    scores = jnp.where(cm[None, :, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bchqk,bckhd->bcqhd", probs, vv)
    out = out.reshape(b, sp, h * cfg.hd)[:, :s]
    return out @ p["wo"].astype(x.dtype)


def kv_quantize(x):
    """(..., hd) -> int8 payload + per-token f32 scale (beyond-paper: int8
    KV cache — halves decode HBM traffic and makes the qwen1.5-32b 32k MHA
    cache fit a v5e (21.5 -> 10.8 GiB/device; EXPERIMENTS.md §Perf)."""
    s = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0 + 1e-8
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / s[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, s


def kv_dequantize(q, s, dtype):
    return q.astype(dtype) * s[..., None].astype(dtype)


def cached_decode_attention_q8(p, x, ck, cv, ks, vs, pos, cfg: ModelConfig):
    """Decode against an int8-quantized cache. ck/cv (B,S,KV,hd) int8,
    ks/vs (B,S,KV) f32. Returns (out, ck, cv, ks, vs). ``pos`` is a scalar
    or per-sequence (B,) write position (see cached_decode_attention)."""
    b = x.shape[0]
    s_max = ck.shape[1]
    pos = jnp.asarray(pos)
    if pos.ndim == 0:
        write = pos % s_max if cfg.window else pos
        rope_pos = jnp.full((b, 1), pos)
        q, k, v = qkv_project(p, x, cfg, rope_pos)
        k8, k_s = kv_quantize(k)
        v8, v_s = kv_quantize(v)
        ck = jax.lax.dynamic_update_slice_in_dim(ck, k8, write, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cv, v8, write, axis=1)
        ks = jax.lax.dynamic_update_slice_in_dim(ks, k_s, write, axis=1)
        vs = jax.lax.dynamic_update_slice_in_dim(vs, v_s, write, axis=1)
        mask = (jnp.arange(s_max) <= pos)[None, None, None, :]
    else:
        write = pos % s_max if cfg.window else pos
        rows = jnp.arange(b)
        q, k, v = qkv_project(p, x, cfg, pos[:, None])
        k8, k_s = kv_quantize(k)
        v8, v_s = kv_quantize(v)
        ck = ck.at[rows, write].set(k8[:, 0])
        cv = cv.at[rows, write].set(v8[:, 0])
        ks = ks.at[rows, write].set(k_s[:, 0])
        vs = vs.at[rows, write].set(v_s[:, 0])
        mask = (jnp.arange(s_max)[None, :] <= pos[:, None])[:, None, None, :]
    kf = kv_dequantize(ck, ks, q.dtype)
    vf = kv_dequantize(cv, vs, q.dtype)
    out = _sdpa(q, kf, vf, mask, cfg)
    return out @ p["wo"].astype(x.dtype), ck, cv, ks, vs


def cached_decode_attention(p, x, cache_k, cache_v, pos, cfg: ModelConfig):
    """One-token decode against a (B, S_max, KV, hd) cache.

    Returns (out (B, 1, D), new_k, new_v). ``pos`` is the write position —
    a scalar applied to every sequence, or a (B,) vector of per-sequence
    positions (continuous batching: each serving slot decodes at its own
    depth, so RoPE phase, cache write row and the causal mask must all be
    per-slot; see serve/engine.py).
    If cfg.window > 0 the cache is a ring buffer of size S_max (= window).
    """
    b = x.shape[0]
    s_max = cache_k.shape[1]
    pos = jnp.asarray(pos)
    kpos = jnp.arange(s_max)
    # slots written so far; for the ring buffer (window mode) every slot is
    # valid once pos >= s_max and they are exactly the last s_max tokens —
    # attention is permutation-invariant over keys so ring order is fine
    if pos.ndim == 0:
        write = pos % s_max if cfg.window else pos
        rope_pos = jnp.full((b, 1), pos)
        q, k, v = qkv_project(p, x, cfg, rope_pos)
        cache_k = jax.lax.dynamic_update_slice_in_dim(
            cache_k, k.astype(cache_k.dtype), write, axis=1)
        cache_v = jax.lax.dynamic_update_slice_in_dim(
            cache_v, v.astype(cache_v.dtype), write, axis=1)
        mask = (kpos <= pos)[None, None, None, :]
    else:
        write = pos % s_max if cfg.window else pos
        rows = jnp.arange(b)
        q, k, v = qkv_project(p, x, cfg, pos[:, None])
        cache_k = cache_k.at[rows, write].set(k[:, 0].astype(cache_k.dtype))
        cache_v = cache_v.at[rows, write].set(v[:, 0].astype(cache_v.dtype))
        mask = (kpos[None, :] <= pos[:, None])[:, None, None, :]
    out = _sdpa(q, cache_k.astype(q.dtype), cache_v.astype(q.dtype), mask, cfg)
    return out @ p["wo"].astype(x.dtype), cache_k, cache_v


# ---------------------------------------------------------------- mlp ------

def mlp_init(key, cfg: ModelConfig, d: Optional[int] = None,
             ff: Optional[int] = None):
    d = d or cfg.d_model
    ff = ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = 1.0 / np.sqrt(d)
    s_out = 1.0 / np.sqrt(ff)
    return {
        "wi": jax.random.normal(k1, (d, ff), jnp.float32) * s_in,
        "wg": jax.random.normal(k2, (d, ff), jnp.float32) * s_in,
        "wo": jax.random.normal(k3, (ff, d), jnp.float32) * s_out,
    }


def apply_mlp(p, x, cfg: ModelConfig):
    """SwiGLU (qwen/olmo/pixtral families) — silu(x wg) * (x wi) wo."""
    g = jax.nn.silu(x @ p["wg"].astype(x.dtype))
    h = x @ p["wi"].astype(x.dtype)
    return (g * h) @ p["wo"].astype(x.dtype)


# ------------------------------------------------------------ embedding ----

def embed_init(key, cfg: ModelConfig):
    k1, k2 = jax.random.split(key)
    p = {"embedding": jax.random.normal(k1, (cfg.vocab, cfg.d_model),
                                        jnp.float32) * 0.02}
    if not cfg.tie_embeddings:
        p["lm_head"] = jax.random.normal(k2, (cfg.d_model, cfg.vocab),
                                         jnp.float32) * 0.02
    return p


def embed(p, tokens, cfg: ModelConfig):
    return p["embedding"][tokens].astype(cdtype(cfg))


def unembed(p, x, cfg: ModelConfig):
    w = p["lm_head"] if not cfg.tie_embeddings else p["embedding"].T
    return (x @ w.astype(x.dtype)).astype(jnp.float32)


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: jax.Array | None = None) -> jax.Array:
    """Mean token CE in f32. logits (B, S, V), labels (B, S) int32."""
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logits.astype(jnp.float32), labels[..., None],
                             axis=-1)[..., 0]
    nll = lse - ll
    if mask is None:
        return jnp.mean(nll)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
