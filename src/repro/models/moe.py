"""Mixture-of-Experts decoder (qwen3-moe family): token-choice top-k routing
with capacity-bounded scatter/gather dispatch (no (T,E,C) one-hot tensors —
DESIGN.md §4), experts sharded over the "model" mesh axis (EP).

Dispatch (per sequence group): position-in-expert via cumsum over the (S, E)
assignment matrix, tokens scattered into an (E, C, D) buffer with
``.at[].add``, expert FFNs as one batched einsum over E, combined back by
gather. Dropped tokens (over capacity) pass through the residual — standard
GShard semantics.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.models.base import ModelConfig
from repro.sharding.act import constrain, constrain_expert


def capacity(cfg: ModelConfig, tokens_per_group: int) -> int:
    c = int(math.ceil(tokens_per_group * cfg.top_k / cfg.n_experts
                      * cfg.capacity_factor))
    return max(c, cfg.top_k)


def moe_init(key, cfg: ModelConfig):
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s_in, s_out = 1.0 / np.sqrt(d), 1.0 / np.sqrt(f)
    return {
        "router": jax.random.normal(k1, (d, e), jnp.float32) * s_in,
        "wi": jax.random.normal(k2, (e, d, f), jnp.float32) * s_in,
        "wg": jax.random.normal(k3, (e, d, f), jnp.float32) * s_in,
        "wo": jax.random.normal(k4, (e, f, d), jnp.float32) * s_out,
    }


def apply_moe(p, x, cfg: ModelConfig):
    """x (B, S, D) -> (B, S, D); groups = sequences."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    c = capacity(cfg, s)
    # router matmul in bf16 (f32 here back-propagates an f32 (B,S,D)-scale
    # cotangent through every layer — §Perf iteration 4e); softmax on the
    # small (B,S,E) logits still runs in f32 for stability
    logits = (x @ p["router"].astype(x.dtype)).astype(jnp.float32)
    gates = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(gates, k)                    # (B, S, k)
    topv = (topv / jnp.sum(topv, axis=-1, keepdims=True)).astype(x.dtype)
    # position of each (token, slot) within its expert, per sequence group
    onehot = jax.nn.one_hot(topi, e, dtype=jnp.int32)       # (B, S, k, E)
    flat = onehot.reshape(b, s * k, e)
    pos = jnp.cumsum(flat, axis=1) - 1                      # (B, S*k, E)
    pos = jnp.sum(pos.reshape(b, s, k, e) * onehot, axis=-1)  # (B, S, k)
    keep = pos < c
    # GATHER-based dispatch (§Perf iteration 4c): scattering D-dim token
    # vectors into the expert-sharded buffer lowers to full-buffer
    # all-reduces under SPMD (measured 5+ TB/step at 235B). Instead we
    # scatter only int32 TOKEN IDS into slots (64x smaller worst case),
    # then build the buffer with a gather — index-sharded gathers stay
    # local. Dropped assignments route to a trash slot; unfilled slots
    # keep the sentinel id S which gathers a zero pad row.
    slot = topi * c + jnp.where(keep, pos, 0)               # (B, S, k)
    rows = jnp.arange(b)[:, None]
    tok_ids = jnp.broadcast_to(jnp.arange(s)[None, :, None], (b, s, k))
    flat_slot = jnp.where(keep, slot, e * c).reshape(b, s * k)
    slot_tok = jnp.full((b, e * c + 1), s, jnp.int32)
    slot_tok = constrain(slot_tok.at[rows, flat_slot].set(
        tok_ids.reshape(b, s * k), mode="drop")[:, : e * c])
    x_pad = constrain(
        jnp.concatenate([x, jnp.zeros((b, 1, d), x.dtype)], axis=1))
    xe = jnp.take_along_axis(x_pad, slot_tok[..., None], axis=1)
    xe = xe.reshape(b, e, c, d)
    # batch over data x experts over model: every device fills its
    # (B rows x E cols) tile locally (see constrain_expert)
    xe = constrain_expert(xe)
    g = jax.nn.silu(jnp.einsum("becd,edf->becf", xe, p["wg"].astype(x.dtype)))
    h = jnp.einsum("becd,edf->becf", xe, p["wi"].astype(x.dtype))
    ye = jnp.einsum("becf,efd->becd", g * h, p["wo"].astype(x.dtype))
    # combine: replicate ye over "model" (one explicit all-gather —
    # ~2.5 GB/device/layer), then SCATTER-ADD each slot's gated output back
    # to its token via slot_tok. The earlier token-indexed GATHER formulation
    # transposed into scatter-adds over sharded dims and cost 23 TB/device of
    # f32 all-reduces per step; this slot-indexed scatter (and its backward,
    # a gather) touches only local/replicated dims (§Perf iterations 4d/4f).
    ye = constrain(ye.reshape(b, e * c, d))
    gate_slot = jnp.zeros((b, e * c + 1), x.dtype)
    gate_slot = gate_slot.at[rows, flat_slot].set(
        topv.reshape(b, s * k), mode="drop")[:, : e * c]
    gate_slot = constrain(gate_slot)
    out = constrain(jnp.zeros((b, s + 1, d), x.dtype))
    out = out.at[rows, slot_tok].add(ye * gate_slot[..., None], mode="drop")
    return constrain(out[:, :s])


def init_layer(key, cfg: ModelConfig):
    k1, k2 = jax.random.split(key)
    return {
        "attn": L.attn_init(k1, cfg),
        "moe": moe_init(k2, cfg),
        "ln1": L.norm_init(cfg, cfg.d_model),
        "ln2": L.norm_init(cfg, cfg.d_model),
    }


def init(key, cfg: ModelConfig):
    keys = jax.random.split(key, cfg.n_layers + 1)
    stacked = jax.vmap(lambda k: init_layer(k, cfg))(keys[:cfg.n_layers])
    return {
        "embed": L.embed_init(keys[-1], cfg),
        "layers": stacked,
        "final_norm": L.norm_init(cfg, cfg.d_model),
    }


def _layer_fwd(p, x, cfg: ModelConfig):
    x = constrain(x)
    if cfg.chunked_attn:
        a = L.chunked_causal_attention(p["attn"],
                                       L.apply_norm(p["ln1"], x, cfg), cfg,
                                       block=cfg.attn_block)
    else:
        a = L.causal_attention(p["attn"], L.apply_norm(p["ln1"], x, cfg), cfg)
    h = x + a
    h = constrain(h)
    h = h + apply_moe(p["moe"], L.apply_norm(p["ln2"], h, cfg), cfg)
    return constrain(h)


def forward(params, batch, cfg: ModelConfig):
    x = constrain(L.embed(params["embed"], batch["tokens"], cfg))
    body = jax.checkpoint(lambda xx, lp: (_layer_fwd(lp, xx, cfg), None))
    x, _ = jax.lax.scan(body, x, params["layers"])
    x = L.apply_norm(params["final_norm"], x, cfg)
    return L.unembed(params["embed"], x, cfg)


def loss_fn(params, batch, cfg: ModelConfig):
    logits = forward(params, batch, cfg)
    return L.cross_entropy(logits[:, :-1], batch["labels"][:, 1:])


# ------------------------------------------------------------- serving -----

def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv, cfg.hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
            "pos": jnp.zeros((), jnp.int32)}


def decode_step(params, cache, tokens, cfg: ModelConfig):
    """One-token decode; MoE dispatch groups over the whole batch."""
    x = L.embed(params["embed"], tokens[:, None], cfg)
    pos = cache["pos"]

    def body(x, scanned):
        lp, ck, cv = scanned
        h = L.apply_norm(lp["ln1"], x, cfg)
        a, nk, nv = L.cached_decode_attention(lp["attn"], h, ck, cv, pos, cfg)
        x = x + a
        h = L.apply_norm(lp["ln2"], x, cfg)
        # batch of B single tokens = one group of B tokens
        moe_out = apply_moe(lp["moe"], h.reshape(1, -1, cfg.d_model), cfg)
        x = x + moe_out.reshape(x.shape)
        return x, (nk, nv)

    x, (nk, nv) = jax.lax.scan(body, x, (params["layers"], cache["k"], cache["v"]))
    x = L.apply_norm(params["final_norm"], x, cfg)
    logits = L.unembed(params["embed"], x, cfg)[:, 0]
    return logits, {"k": nk, "v": nv, "pos": pos + 1}
