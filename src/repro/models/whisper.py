"""Whisper-medium encoder-decoder backbone (conv/mel frontend is a STUB per
the assignment spec — ``input_specs()`` provides precomputed frame embeddings
(B, S_enc, D) directly).

Encoder: bidirectional pre-LN transformer with sinusoidal positions.
Decoder: causal self-attention + cross-attention to the encoder output,
learned positions. Whisper uses parametric LayerNorm and no RoPE.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.models.base import ModelConfig
from repro.sharding.act import constrain

_MAX_DEC = 4096  # learned decoder positions allocated (whisper ships 448)


def _mlp_init(key, cfg):
    # whisper MLP is GELU, not gated: reuse wi/wo, no wg
    k1, k2 = jax.random.split(key)
    return {"wi": jax.random.normal(k1, (cfg.d_model, cfg.d_ff), jnp.float32) / np.sqrt(cfg.d_model),
            "wo": jax.random.normal(k2, (cfg.d_ff, cfg.d_model), jnp.float32) / np.sqrt(cfg.d_ff)}


def _mlp(p, x):
    return jax.nn.gelu(x @ p["wi"].astype(x.dtype)) @ p["wo"].astype(x.dtype)


def _enc_layer_init(key, cfg):
    k1, k2 = jax.random.split(key)
    return {"attn": L.attn_init(k1, cfg), "mlp": _mlp_init(k2, cfg),
            "ln1": L.norm_init(cfg, cfg.d_model),
            "ln2": L.norm_init(cfg, cfg.d_model)}


def _dec_layer_init(key, cfg):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"self_attn": L.attn_init(k1, cfg), "cross_attn": L.attn_init(k2, cfg),
            "mlp": _mlp_init(k3, cfg),
            "ln1": L.norm_init(cfg, cfg.d_model),
            "ln2": L.norm_init(cfg, cfg.d_model),
            "ln3": L.norm_init(cfg, cfg.d_model)}


def init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 4)
    enc = jax.vmap(lambda k: _enc_layer_init(k, cfg))(
        jax.random.split(ks[0], cfg.enc_layers))
    dec = jax.vmap(lambda k: _dec_layer_init(k, cfg))(
        jax.random.split(ks[1], cfg.n_layers))
    return {
        "embed": L.embed_init(ks[2], cfg),
        "dec_pos": jax.random.normal(ks[3], (_MAX_DEC, cfg.d_model),
                                     jnp.float32) * 0.01,
        "enc_layers": enc,
        "dec_layers": dec,
        "enc_norm": L.norm_init(cfg, cfg.d_model),
        "dec_norm": L.norm_init(cfg, cfg.d_model),
    }


def _sinusoid(s, d, dtype):
    pos = np.arange(s)[:, None]
    i = np.arange(d // 2)[None, :]
    ang = pos / (10000 ** (2 * i / d))
    emb = np.concatenate([np.sin(ang), np.cos(ang)], axis=-1)
    return jnp.asarray(emb, dtype)


def encode(params, frames, cfg: ModelConfig):
    """frames (B, S_enc, D) stub embeddings -> encoder output (B, S_enc, D)."""
    x = frames.astype(L.cdtype(cfg))
    x = x + _sinusoid(x.shape[1], cfg.d_model, x.dtype)[None]

    def body(xx, lp):
        xx = constrain(xx)
        h = L.apply_norm(lp["ln1"], xx, cfg)
        xx = xx + L.causal_attention(lp["attn"], h, cfg, causal=False)
        xx = xx + _mlp(lp["mlp"], L.apply_norm(lp["ln2"], xx, cfg))
        return constrain(xx), None

    x, _ = jax.lax.scan(jax.checkpoint(body), x, params["enc_layers"])
    return L.apply_norm(params["enc_norm"], x, cfg)


def _cross_attention(p, x, enc_kv, cfg):
    """x (B, Sd, D) queries against precomputed encoder K/V."""
    b, s, _ = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv, cfg.hd
    q = (x @ p["wq"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
    q = q.reshape(b, s, h, hd)
    k, v = enc_kv
    mask = jnp.ones((1, 1, s, k.shape[1]), bool)
    out = L._sdpa(q, k, v, mask, cfg)
    return out @ p["wo"].astype(x.dtype)


def _enc_kv(p, enc_out, cfg):
    b, se, _ = enc_out.shape
    kv, hd = cfg.n_kv, cfg.hd
    k = (enc_out @ p["wk"].astype(enc_out.dtype)).reshape(b, se, kv, hd)
    v = (enc_out @ p["wv"].astype(enc_out.dtype)).reshape(b, se, kv, hd)
    return k, v


def decode(params, tokens, enc_out, cfg: ModelConfig):
    """Teacher-forced decoder -> logits (B, S_dec, V)."""
    x = L.embed(params["embed"], tokens, cfg)
    s = tokens.shape[1]
    x = x + params["dec_pos"][:s][None].astype(x.dtype)

    def body(xx, lp):
        xx = constrain(xx)
        h = L.apply_norm(lp["ln1"], xx, cfg)
        no_rope = cfg.replace(rope_theta=0.0)
        xx = xx + L.causal_attention(lp["self_attn"], h, no_rope)
        h = L.apply_norm(lp["ln2"], xx, cfg)
        xx = xx + _cross_attention(lp["cross_attn"], h,
                                   _enc_kv(lp["cross_attn"], enc_out, cfg), cfg)
        xx = xx + _mlp(lp["mlp"], L.apply_norm(lp["ln3"], xx, cfg))
        return constrain(xx), None

    x, _ = jax.lax.scan(jax.checkpoint(body), x, params["dec_layers"])
    x = L.apply_norm(params["dec_norm"], x, cfg)
    return L.unembed(params["embed"], x, cfg)


def forward(params, batch, cfg: ModelConfig):
    enc_out = encode(params, batch["frames"], cfg)
    return decode(params, batch["tokens"], enc_out, cfg)


def loss_fn(params, batch, cfg: ModelConfig):
    logits = forward(params, batch, cfg)
    return L.cross_entropy(logits[:, :-1], batch["labels"][:, 1:])


# ------------------------------------------------------------- serving -----

def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16,
               enc_len: int = 0):
    l, kv, hd = cfg.n_layers, cfg.n_kv, cfg.hd
    enc_len = enc_len or max_len
    return {
        "k": jnp.zeros((l, batch, max_len, kv, hd), dtype),
        "v": jnp.zeros((l, batch, max_len, kv, hd), dtype),
        # cross K/V precomputed once from the encoder output at prefill
        "xk": jnp.zeros((l, batch, enc_len, kv, hd), dtype),
        "xv": jnp.zeros((l, batch, enc_len, kv, hd), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


def prefill_cross(params, enc_out, cache, cfg: ModelConfig):
    """Populate cross-attention K/V from the encoder output."""
    def body(_, lp):
        k, v = _enc_kv(lp["cross_attn"], enc_out, cfg)
        return None, (k, v)
    _, (xk, xv) = jax.lax.scan(body, None, params["dec_layers"])
    return dict(cache, xk=xk.astype(cache["xk"].dtype),
                xv=xv.astype(cache["xv"].dtype))


def decode_step(params, cache, tokens, cfg: ModelConfig):
    x = L.embed(params["embed"], tokens[:, None], cfg)
    pos = cache["pos"]
    x = x + params["dec_pos"][pos % _MAX_DEC][None, None].astype(x.dtype)
    no_rope = cfg.replace(rope_theta=0.0)

    def body(x, scanned):
        lp, ck, cv, xk, xv = scanned
        h = L.apply_norm(lp["ln1"], x, cfg)
        a, nk, nv = L.cached_decode_attention(lp["self_attn"], h, ck, cv, pos,
                                              no_rope)
        x = x + a
        h = L.apply_norm(lp["ln2"], x, cfg)
        x = x + _cross_attention(lp["cross_attn"], h,
                                 (xk.astype(x.dtype), xv.astype(x.dtype)), cfg)
        x = x + _mlp(lp["mlp"], L.apply_norm(lp["ln3"], x, cfg))
        return x, (nk, nv)

    x, (nk, nv) = jax.lax.scan(
        body, x, (params["dec_layers"], cache["k"], cache["v"],
                  cache["xk"], cache["xv"]))
    x = L.apply_norm(params["dec_norm"], x, cfg)
    logits = L.unembed(params["embed"], x, cfg)[:, 0]
    return logits, dict(cache, k=nk, v=nv, pos=pos + 1)
