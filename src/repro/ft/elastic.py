"""Elastic scaling (DESIGN.md §6): re-lower onto a different mesh extent and
reshard checkpointed state.

Because shardings are derived from logical rules (sharding/rules.py), any
mesh whose axis sizes divide the logical dims is valid — growing or shrinking
the ("pod","data") extent only changes the spec resolution. The elastic path
is therefore: checkpoint → build new mesh → re-derive specs → device_put the
restored host state → re-jit. ``plan_remesh`` picks the largest usable device
count (whole data-parallel replicas) after failures.
"""
from __future__ import annotations

import dataclasses

import jax

from repro import compat
from repro.launch.mesh import make_production_mesh  # noqa: F401  (re-export)


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    n_devices: int
    data: int
    model: int

    def make(self):
        return compat.make_mesh((self.data, self.model), ("data", "model"))


def plan_remesh(n_alive: int, model_parallel: int) -> MeshPlan:
    """Largest mesh using whole model-parallel groups on alive devices."""
    assert n_alive >= model_parallel, "fewer devices than one model replica"
    data = n_alive // model_parallel
    return MeshPlan(n_devices=data * model_parallel, data=data,
                    model=model_parallel)


def reshard(state, mesh, specs):
    """Host/old-mesh state -> new mesh placement."""
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P
    return jax.tree_util.tree_map(
        lambda x, s: jax.device_put(
            x, NamedSharding(mesh, s if isinstance(s, P) else P())),
        state, specs)
