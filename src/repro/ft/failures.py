"""Failure handling for the training driver (DESIGN.md §6).

``FaultTolerantLoop`` wraps the step function: any step raising
``WorkerFailure`` (injected in tests; on a real pod this is the surfaced
XLA/runtime error or a missed heartbeat) triggers restore-from-latest-valid
checkpoint and resumption. A ``HeartbeatMonitor`` tracks per-rank liveness
the way a pod-level driver would; ranks missing ``timeout`` seconds are
declared dead (tests drive this clock manually).
"""
from __future__ import annotations

import time
from typing import Callable


class WorkerFailure(RuntimeError):
    """A (simulated or real) device/host failure during a step."""


class HeartbeatMonitor:
    def __init__(self, n_ranks: int, timeout: float = 60.0):
        self.timeout = timeout
        self.last = {r: time.monotonic() for r in range(n_ranks)}

    def beat(self, rank: int, now: float | None = None):
        self.last[rank] = now if now is not None else time.monotonic()

    def dead_ranks(self, now: float | None = None) -> list[int]:
        now = now if now is not None else time.monotonic()
        return [r for r, t in self.last.items() if now - t > self.timeout]


class FaultTolerantLoop:
    """Run steps with checkpoint/restart semantics.

    step_fn(state, batch) -> (state, metrics); state is any pytree dict.
    """

    def __init__(self, step_fn: Callable, ckpt_manager, pipeline,
                 save_every: int = 50, max_restarts: int = 8):
        self.step_fn = step_fn
        self.ckpt = ckpt_manager
        self.pipeline = pipeline
        self.save_every = save_every
        self.max_restarts = max_restarts
        self.restarts = 0

    def _restore(self, state):
        got = self.ckpt.restore(state)
        if got is None:
            # no checkpoint yet: restart from the initial state / cursor 0
            self.pipeline.load_state_dict({"seed": self.pipeline.seed,
                                           "step": 0})
            return state, 0
        st, extra, step = got
        if "pipeline" in extra:
            self.pipeline.load_state_dict(extra["pipeline"])
        return st, step

    def run(self, state, n_steps: int, inject: Callable[[int], bool] | None = None):
        """Returns (final_state, metrics_log). ``inject(step)`` true ->
        simulate a worker failure at that step (before it commits)."""
        log = []
        step = 0
        # resume if a checkpoint exists
        state, step = self._restore(state)
        while step < n_steps:
            try:
                if inject is not None and inject(step):
                    raise WorkerFailure(f"injected failure at step {step}")
                batch = self.pipeline.next()
                state, metrics = self.step_fn(state, batch)
                step += 1
                log.append({"step": step, **{k: float(v) for k, v in metrics.items()}})
                if step % self.save_every == 0 or step == n_steps:
                    self.ckpt.save(step, state,
                                   extra={"pipeline": self.pipeline.state_dict()})
            except WorkerFailure:
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    raise
                state, step = self._restore(state)
        return state, log
