"""Straggler detection & mitigation (DESIGN.md §6).

Detection: per-rank step-time EWMA; a rank is a straggler when its EWMA
exceeds ``threshold`` × the fleet median. Mitigation on a real pod maps to
the same re-lower path as elastic scaling (shrink the slow rank's data
shard / evict it); here the policy object is exercised directly in tests and
by the training driver's logging.
"""
from __future__ import annotations

import statistics
from collections import defaultdict


class StragglerDetector:
    def __init__(self, alpha: float = 0.3, threshold: float = 1.8,
                 min_samples: int = 3):
        self.alpha = alpha
        self.threshold = threshold
        self.min_samples = min_samples
        self.ewma: dict[int, float] = {}
        self.count: dict[int, int] = defaultdict(int)

    def record(self, rank: int, step_time: float):
        prev = self.ewma.get(rank)
        self.ewma[rank] = step_time if prev is None else \
            self.alpha * step_time + (1 - self.alpha) * prev
        self.count[rank] += 1

    def stragglers(self) -> list[int]:
        ready = {r: t for r, t in self.ewma.items()
                 if self.count[r] >= self.min_samples}
        if len(ready) < 2:
            return []
        med = statistics.median(ready.values())
        return [r for r, t in ready.items() if t > self.threshold * med]

    def mitigation(self, rank: int) -> str:
        """Policy: first rebalance (smaller shard), then evict via elastic."""
        e = self.ewma.get(rank, 0.0)
        ready = [t for r, t in self.ewma.items() if r != rank]
        med = statistics.median(ready) if ready else e
        return "evict" if med and e > 3.0 * med else "rebalance"
