"""AdamW with decoupled weight decay, global-norm clipping and cosine
schedule — pure JAX (no optax in this environment; DESIGN.md §2)."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup -> cosine decay to min_lr_ratio·lr."""
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(cfg.warmup_steps, 1)
    prog = (s - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(s < cfg.warmup_steps, jnp.minimum(warm, 1.0), cos)


def init(params: Any) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads: Any, max_norm: float) -> tuple[Any, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), norm


def update(grads: Any, state: dict, params: Any, cfg: AdamWConfig
           ) -> tuple[Any, dict, dict]:
    """Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state["step"] + 1
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * gf
        v = b2 * v + (1 - b2) * gf * gf
        mh = m / bc1
        vh = v / bc2
        newp = p - lr * (mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p)
        return newp.astype(p.dtype), m, v

    out = jax.tree_util.tree_map(upd, params, grads, state["m"], state["v"])
    flat, treedef = jax.tree_util.tree_flatten(out, is_leaf=lambda x: isinstance(x, tuple))
    newp = jax.tree_util.tree_unflatten(treedef, [t[0] for t in flat])
    newm = jax.tree_util.tree_unflatten(treedef, [t[1] for t in flat])
    newv = jax.tree_util.tree_unflatten(treedef, [t[2] for t in flat])
    return newp, {"m": newm, "v": newv, "step": step}, \
        {"lr": lr, "grad_norm": gnorm}
