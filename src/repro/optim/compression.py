"""Gradient compression for the thin cross-pod (DCN) hop (DESIGN.md §6).

int8 symmetric quantization with per-tensor scales and error feedback: the
quantization residual is carried to the next step so the compressed SGD
direction stays unbiased over time (Seide et al. / EF-SGD). Used as the
``grad_transform`` hook of train/step.py, wrapping the cross-pod psum:

    g_q, state = compress(g + state.residual)
    g_hat      = decompress(psum(g_q))          # 4x fewer DCN bytes
    residual'  = (g + residual) - decompress(g_q)

The quantize/dequantize pair is exact-enough to keep training loss curves
within noise of uncompressed (tested in tests/test_ft.py).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class EFState(NamedTuple):
    residual: Any     # same tree as grads


def init_state(grads_like: Any) -> EFState:
    return EFState(residual=jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_like))


def quantize(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_tree(grads: Any, state: EFState) -> tuple[Any, Any, EFState]:
    """-> (q_tree, scale_tree, new_state). Error feedback included."""
    def one(g, r):
        gf = g.astype(jnp.float32) + r
        q, s = quantize(gf)
        new_r = gf - dequantize(q, s)
        return q, s, new_r

    flat = jax.tree_util.tree_map(one, grads, state.residual)
    leaves, treedef = jax.tree_util.tree_flatten(
        flat, is_leaf=lambda x: isinstance(x, tuple) and len(x) == 3)
    qs = jax.tree_util.tree_unflatten(treedef, [l[0] for l in leaves])
    ss = jax.tree_util.tree_unflatten(treedef, [l[1] for l in leaves])
    rs = jax.tree_util.tree_unflatten(treedef, [l[2] for l in leaves])
    return qs, ss, EFState(residual=rs)


def decompress_tree(qs: Any, ss: Any) -> Any:
    return jax.tree_util.tree_map(dequantize, qs, ss)


def make_compressed_psum(axis: str):
    """shard_map-side helper: int8-quantized psum with dequantize."""
    def fn(grads, state: EFState):
        qs, ss, state = compress_tree(grads, state)
        # int8 tensors sum without overflow only after widening: psum in f32
        # of the dequantized values would defeat the wire saving, so the
        # wire format is int8 payload + f32 scale; the sum of dequantized
        # per-pod values equals psum(int32 widened) * scale when scales are
        # shared — we psum widened int32 and the max scale (conservative).
        wide = jax.tree_util.tree_map(lambda q: q.astype(jnp.int32), qs)
        summed = jax.lax.psum(wide, axis)
        scale = jax.tree_util.tree_map(lambda s: jax.lax.pmax(s, axis), ss)
        out = jax.tree_util.tree_map(
            lambda w, s: w.astype(jnp.float32) * s, summed, scale)
        return out, state
    return fn
