"""Collective-byte accounting from compiled (post-SPMD) HLO text.

Optimized HLO prints only RESULT shapes inline (operand types are bare
names), so per-collective traffic is derived from the result shape and the
replica-group size ``g`` using ring-algorithm wire bytes per device:

    all-reduce          2·r·(g-1)/g          (reduce-scatter + all-gather)
    all-gather          r·(g-1)/g
    reduce-scatter      r·(g-1)               (input = r·g, sends (g-1)/g of it)
    all-to-all          r·(g-1)/g
    collective-permute  r

This is the actual ICI traffic model (slightly stronger than the raw
"operand bytes" proxy). Instructions inside while-loop bodies are multiplied
by the loop trip count — XLA shows a loop body once, which would otherwise
undercount a scanned-layer model by ~n_layers× (measured in DESIGN.md §7).

Trip counts are recovered from the loop-condition computation (the constant
bound of its compare) — the standard shape for lax.scan lowerings. Nested
loops multiply. Fusions cannot contain collectives, so only while/call/
conditional edges are walked.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
# result types of while are big space-containing tuples: anchor on the
# opcode + attribute names only
_WHILE_RE = re.compile(
    r"\bwhile\(.*?condition=%?([\w\.\-]+).*?body=%?([\w\.\-]+)", re.S)
_CALL_RE = re.compile(r"(?:to_apply|called_computations?)=\{?%?([\w\.\-]+)")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _split_computations(text: str) -> Dict[str, list[str]]:
    """Computation headers are non-indented ``%name (args…) -> type {`` lines
    (args may contain nested parens — match structurally, not by regex)."""
    comps: Dict[str, list[str]] = {}
    cur = None
    for line in text.splitlines():
        s = line.rstrip()
        if not line.startswith(" ") and s.endswith("{") and ") -> " in s:
            tok = s.split()[1] if s.startswith("ENTRY") else s.split()[0]
            cur = tok.lstrip("%")
            comps[cur] = []
        elif cur is not None:
            if s == "}":
                cur = None
            else:
                comps[cur].append(line)
    return comps


def _entry_name(text: str) -> str | None:
    m = re.search(r"^ENTRY\s+%?([\w\.\-]+)", text, re.M)
    return m.group(1) if m else None


_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")


def _group_size(line: str, default: int = 2) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return default


def _result_bytes(line: str) -> int:
    """Bytes of the instruction's result (tuples summed)."""
    lhs = line.split(" = ", 1)
    if len(lhs) != 2:
        return 0
    # result type(s) appear between '=' and the opcode
    rhs = lhs[1]
    opi = min((rhs.find(op) for op in COLLECTIVES if rhs.find(op) >= 0),
              default=len(rhs))
    head = rhs[:opi]
    sizes = [_shape_bytes(d, dims) for d, dims in _SHAPE_RE.findall(head)]
    if not sizes:
        return 0
    # async -start results are (operand, result) tuples: take the larger
    return max(sizes) if "-start" in rhs[opi:opi + 40] else sum(sizes)


def _collective_bytes_of_line(line: str) -> tuple[str, int] | None:
    for op in COLLECTIVES:
        m = re.search(rf"=\s*[^=]*\s{op}(?:-start)?\(", line)
        if m:
            r = _result_bytes(line)
            g = _group_size(line)
            if op == "all-reduce":
                wire = 2.0 * r * (g - 1) / g
            elif op == "all-gather":
                wire = r * (g - 1) / g
            elif op == "reduce-scatter":
                wire = float(r) * (g - 1)
            elif op == "all-to-all":
                wire = r * (g - 1) / g
            else:  # collective-permute
                wire = float(r)
            return op, int(wire)
        if re.search(rf"=\s*[^=]*\s{op}-done\(", line):
            return None
    return None


def _trip_count(cond_lines: list[str]) -> int:
    consts = [int(c) for l in cond_lines for c in _CONST_RE.findall(l)]
    return max(consts) if consts else 1


def collective_bytes(text: str) -> dict:
    """-> {"total": int, "per_op": {op: bytes}, "counts": {op: n}} (per device)."""
    comps = _split_computations(text)
    entry = _entry_name(text)
    if entry is None and comps:
        entry = next(iter(comps))

    # edges: parent -> [(child, multiplier)]
    edges: Dict[str, list] = defaultdict(list)
    for name, lines in comps.items():
        for line in lines:
            wm = _WHILE_RE.search(line)
            if wm:
                cond, body = wm.group(1), wm.group(2)
                trips = _trip_count(comps.get(cond, []))
                edges[name].append((body, trips))
                edges[name].append((cond, trips))
                continue
            for cm in _CALL_RE.finditer(line):
                edges[name].append((cm.group(1), 1))

    # accumulate multipliers via DFS from entry
    mult: Dict[str, int] = defaultdict(int)
    stack = [(entry, 1)]
    seen_pairs = set()
    while stack:
        name, m = stack.pop()
        if name not in comps or (name, m) in seen_pairs:
            continue
        seen_pairs.add((name, m))
        mult[name] += m
        for child, k in edges.get(name, []):
            stack.append((child, m * k))

    per_op: Dict[str, int] = defaultdict(int)
    counts: Dict[str, int] = defaultdict(int)
    for name, lines in comps.items():
        m = mult.get(name, 0)
        if m == 0:
            continue
        for line in lines:
            got = _collective_bytes_of_line(line)
            if got:
                op, b = got
                per_op[op] += b * m
                counts[op] += m
    return {"total": int(sum(per_op.values())),
            "per_op": {k: int(v) for k, v in per_op.items()},
            "counts": dict(counts)}


def top_collectives(text: str, k: int = 12) -> list[dict]:
    """The k largest collectives (wire bytes × loop multiplier) with their
    result shapes — the §Perf iteration's profile."""
    comps = _split_computations(text)
    entry = _entry_name(text)
    edges: Dict[str, list] = defaultdict(list)
    for name, lines in comps.items():
        for line in lines:
            wm = _WHILE_RE.search(line)
            if wm:
                trips = _trip_count(comps.get(wm.group(1), []))
                edges[name].append((wm.group(2), trips))
                edges[name].append((wm.group(1), trips))
            else:
                for cm in _CALL_RE.finditer(line):
                    edges[name].append((cm.group(1), 1))
    mult: Dict[str, int] = defaultdict(int)
    stack = [(entry, 1)]
    seen = set()
    while stack:
        name, m = stack.pop()
        if name not in comps or (name, m) in seen:
            continue
        seen.add((name, m))
        mult[name] += m
        for child, kk in edges.get(name, []):
            stack.append((child, m * kk))
    out = []
    for name, lines in comps.items():
        m = mult.get(name, 0)
        if m == 0:
            continue
        for line in lines:
            got = _collective_bytes_of_line(line)
            if got:
                op, b = got
                shape = _SHAPE_RE.search(line.split(" = ", 1)[-1])
                out.append({"op": op, "bytes": b * m, "mult": m,
                            "shape": shape.group(0) if shape else "?",
                            "line": line.strip()[:120]})
    out.sort(key=lambda r: -r["bytes"])
    return out[:k]


def while_trip_counts(text: str) -> list[int]:
    comps = _split_computations(text)
    out = []
    for lines in comps.values():
        for line in lines:
            wm = _WHILE_RE.search(line)
            if wm:
                out.append(_trip_count(comps.get(wm.group(1), [])))
    return out
