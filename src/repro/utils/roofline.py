"""Three-term roofline model for TPU v5e (DESIGN.md §7).

    t_compute    = HLO_FLOPs       / (chips · 197e12 FLOP/s bf16)
    t_memory     = HLO_bytes       / (chips · 819e9  B/s HBM)
    t_collective = collective_bytes/ (chips · 50e9   B/s per ICI link)

``compiled.cost_analysis()`` runs on the post-SPMD per-device program, so
HLO_FLOPs / HLO_bytes are PER-DEVICE (verified empirically: an 8-way-sharded
matmul reports global/8). Collective bytes from utils/hlo.py are likewise
per-device. The spec's ``HLO_FLOPs/(chips·peak)`` is therefore computed as
``flops_per_device/peak`` — identical quantity. MODEL_FLOPS uses the
paper-standard 6·N·D (train) / 2·N·D (per decoded token) with N = active
params and is GLOBAL (divided across chips for the useful-compute ratio).
"""
from __future__ import annotations

import dataclasses

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # B/s / chip
ICI_BW = 50e9              # B/s / link


@dataclasses.dataclass
class Roofline:
    t_compute: float
    t_memory: float
    t_collective: float
    model_flops: float
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float
    chips: int

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def step_time(self) -> float:
        """Perfect-overlap bound: the dominant term is the step time."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def hlo_flops_global(self) -> float:
        return self.hlo_flops * self.chips

    @property
    def useful_ratio(self) -> float:
        g = self.hlo_flops_global
        return self.model_flops / g if g else 0.0

    @property
    def mfu_bound(self) -> float:
        """Model FLOPs over chip-seconds at the roofline step time."""
        denom = self.chips * PEAK_FLOPS * self.step_time
        return self.model_flops / denom if denom else 0.0

    def to_dict(self) -> dict:
        return {
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective, "dominant": self.dominant,
            "model_flops": self.model_flops, "hlo_flops": self.hlo_flops,
            "hlo_bytes": self.hlo_bytes,
            "collective_bytes_per_device": self.collective_bytes,
            "useful_ratio": self.useful_ratio, "mfu_bound": self.mfu_bound,
            "chips": self.chips,
        }


def make(hlo_flops_per_dev: float, hlo_bytes_per_dev: float,
         collective_bytes_per_dev: float, chips: int,
         model_flops: float) -> Roofline:
    return Roofline(
        t_compute=hlo_flops_per_dev / PEAK_FLOPS,
        t_memory=hlo_bytes_per_dev / HBM_BW,
        t_collective=collective_bytes_per_dev / ICI_BW,
        model_flops=model_flops, hlo_flops=hlo_flops_per_dev,
        hlo_bytes=hlo_bytes_per_dev,
        collective_bytes=collective_bytes_per_dev, chips=chips)


def model_flops_for(cfg, shape_info: dict) -> float:
    """6·N_active·tokens for train, 2·N_active·tokens for inference."""
    n = cfg.active_param_count()
    kind = shape_info["kind"]
    if kind == "train":
        if cfg.family == "whisper":
            tokens = shape_info["batch"] * (shape_info["seq"] + cfg.dec_len)
        else:
            tokens = shape_info["batch"] * shape_info["seq"]
        return 6.0 * n * tokens
    if kind == "prefill":
        tokens = shape_info["batch"] * shape_info["seq"]
        return 2.0 * n * tokens
    # decode: one token per sequence in the batch
    return 2.0 * n * shape_info["batch"]
