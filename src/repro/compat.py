"""jax version-compat surface for the multi-device path (DESIGN.md §4).

The distributed Dynamic Prober targets the modern sharding API
(``jax.shard_map`` with ``check_vma``, ``jax.make_mesh`` with
``axis_types``), but the pinned image ships jax 0.4.37 where

* ``shard_map`` lives in ``jax.experimental.shard_map`` and its replication
  check is spelled ``check_rep`` (renamed ``check_vma`` in jax >= 0.7);
* ``jax.make_mesh`` exists but takes no ``axis_types`` kwarg, and
  ``jax.sharding.AxisType`` does not exist at all.

Every mesh/shard_map construction in this repo goes through the two
dispatchers below instead of touching ``jax.*`` directly, so the same code
runs on the pinned version and on current jax without conditionals at the
call sites. Dispatch is by feature probe (``inspect.signature``), not
version string parsing — point releases have moved these kwargs around.
"""
from __future__ import annotations

import inspect
from typing import Any, Callable, Sequence

import jax

__all__ = ["shard_map", "make_mesh", "auto_axis_types"]


def _kwargs_of(fn: Callable) -> set[str]:
    try:
        return set(inspect.signature(fn).parameters)
    except (TypeError, ValueError):      # C-implemented / exotic callables
        return set()


def auto_axis_types(n_axes: int):
    """``(AxisType.Auto,) * n_axes`` where the enum exists, else None."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return None
    return (axis_type.Auto,) * n_axes


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str],
              *, devices=None) -> jax.sharding.Mesh:
    """``jax.make_mesh`` with Auto axis types where the kwarg exists.

    On jax >= 0.5 explicit ``axis_types=(AxisType.Auto, ...)`` keeps the
    mesh out of the sharding-in-types ("explicit") mode this codebase does
    not use; on 0.4.x the kwarg (and the enum) don't exist and Auto is the
    only behaviour, so it is simply dropped.
    """
    kwargs: dict[str, Any] = {}
    if devices is not None:
        kwargs["devices"] = devices
    accepted = _kwargs_of(jax.make_mesh)
    if "axis_types" in accepted:
        types = auto_axis_types(len(tuple(axis_names)))
        if types is not None:
            kwargs["axis_types"] = types
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kwargs)


def _resolve_shard_map() -> tuple[Callable, str | None]:
    """The callable plus the name of its replication-check kwarg."""
    fn = getattr(jax, "shard_map", None)
    if fn is None:
        from jax.experimental.shard_map import shard_map as fn  # 0.4.x
    accepted = _kwargs_of(fn)
    for name in ("check_vma", "check_rep"):
        if name in accepted:
            return fn, name
    return fn, None


def shard_map(f: Callable | None = None, *, mesh, in_specs, out_specs,
              check_vma: bool = True) -> Callable:
    """Version-dispatching ``shard_map``.

    Accepts the modern ``check_vma`` spelling and translates it to
    ``check_rep`` on jax 0.4.x (semantics are the same: statically verify
    that out_specs-replicated outputs really are replicated — the
    distributed prober disables it because its psum-free build step returns
    per-shard values the checker cannot prove replicated). Usable directly
    or as ``partial``-style decorator, mirroring ``jax.shard_map``.
    """
    fn, check_kw = _resolve_shard_map()
    kwargs: dict[str, Any] = dict(mesh=mesh, in_specs=in_specs,
                                  out_specs=out_specs)
    if check_kw is not None:
        kwargs[check_kw] = check_vma
    if f is None:
        return lambda g: fn(g, **kwargs)
    return fn(f, **kwargs)
