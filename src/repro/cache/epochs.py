"""Per-bucket ingest epochs — the cache invalidation layer (DESIGN.md §12).

The serving cache (estimate_cache.py) may only serve a stored estimate if
every bucket the original probe visited is untouched by every ingest since.
The probed buckets of a query are exactly the buckets within Hamming
distance ``probed_k`` of its code (rings 0..probed_k, DESIGN.md §3), and
the capacity-padded layout (DESIGN.md §10) already maintains the perfect
per-bucket epoch for free: **its population**. Points are only ever added
(the paper's §5 stream has no deletes), codes of live points are
bit-stable while W is (lsh.project_raw), and a bucket's Hamming distance
to a fixed query code never changes — so the sum of ``bucket_sizes`` over
a query's probed ball is monotone non-decreasing, and it moved **iff**
some ingest landed a point inside a probed ring (including ingests that
CREATE a new bucket there: the new bucket enters the ball carrying its
population). No hashed counters, no collisions, no false hits and no
false invalidations — the check is exact.

What still needs explicit state is the GENERATION of the hash functions:
Alg. 7's W renormalisation can move the widths (a new point extended a
projection extreme), after which every live point's code may shift and
every entry's snapshot geometry is void. ``EpochState.params_epoch``
counts those generations; the fixed-shape ingest step bumps it only when
``W`` actually changed — which, with offset-free retained projections
(``lsh.project_raw``), is bitwise-exactly "some extreme moved", not
"every update" (ulp drift used to flush the cache on each ingest).

The freshness check at lookup is one (B, K) Hamming compare + masked sum
per (query, table) — the probe's own ring construction, minus everything
after it — and the serving layer elides it statically until the first
ingest actually happens. Stale entries are never swept: they die lazily
when the check fails, and the re-probe overwrites them in place.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class EpochState(NamedTuple):
    """Ingest bookkeeping carried in the ProberState (both uint32)."""
    params_epoch: jax.Array  # () hash-function generation (W renorm bumps)
    n_ingested: jax.Array    # () total points ingested (diagnostics)


def init_epochs() -> EpochState:
    """Fresh counters — the population-based design needs no per-table
    state (module docstring)."""
    return EpochState(params_epoch=jnp.uint32(0), n_ingested=jnp.uint32(0))


def ingest_bump(ep: EpochState, n_new: jax.Array,
                w_changed: jax.Array) -> EpochState:
    """Fold one ingest batch into the bookkeeping (fixed-shape; jit-safe
    inside the recompile-free update step, DESIGN.md §10). ``w_changed``
    flags an Alg. 7 renormalisation that moved a width — the whole cache
    generation is then retired via ``params_epoch``."""
    return EpochState(
        params_epoch=ep.params_epoch + w_changed.astype(jnp.uint32),
        n_ingested=ep.n_ingested + n_new.astype(jnp.uint32))


def ball_sums(bucket_codes: jax.Array, bucket_sizes: jax.Array,
              n_buckets: jax.Array, qcodes: jax.Array,
              probed_k: jax.Array) -> jax.Array:
    """Per-table probed-ball populations — the exact invalidation signal.

    ``bucket_codes`` (L, B, K) / ``bucket_sizes`` (L, B) / ``n_buckets``
    (L,) are the index's bucket layout; ``qcodes`` (..., L, K) the query
    codes; ``probed_k`` (..., L) the deepest ring each probe folded.
    Returns (..., L) int32 — the number of live points in buckets within
    distance ``probed_k`` of the query code (rings 0..probed_k). Capacity-
    padding sentinel rows sit past ``n_buckets`` and are masked.
    """
    nt, nb_ax, _ = bucket_codes.shape
    row_live = jnp.arange(nb_ax)[None, :] < n_buckets[:, None]  # (L, B)

    def per_table(bc, live, sizes, qc, pk):
        dist = jnp.sum(bc != qc[None, :], axis=-1)              # (B,)
        return jnp.sum(jnp.where(live & (dist <= pk), sizes, 0))

    def one(qc, pk):                                            # (L, K)/(L,)
        return jax.vmap(per_table)(bucket_codes, row_live, bucket_sizes,
                                   qc, pk)

    batch = qcodes.shape[:-2]
    flat_q = qcodes.reshape((-1,) + qcodes.shape[-2:])
    flat_k = probed_k.reshape((-1, probed_k.shape[-1]))
    out = jax.vmap(one)(flat_q, flat_k)
    return out.reshape(batch + (nt,)).astype(jnp.int32)
