"""Fixed-capacity estimate cache — pure-array storage, CLOCK eviction
(DESIGN.md §12).

The cache is a NamedTuple of fixed-shape arrays (jit/donate friendly, no
Python dicts on the hot path): a KEY table of per-table LSH bucket
signatures (the query's (L, K) bucket codes — computed for free by the
index), an exact-query fingerprint, and a quantized tau band; a VALUE
table of estimates + sample stats; per-entry epoch snapshots
(:mod:`repro.cache.epochs`) for the ingest-invalidation check; and
CLOCK/second-chance metadata (a ``ref`` bit per entry, one clock hand).

Key semantics (the ``reuse_tol`` knob):

* ``reuse_tol == 0`` — fully strict: a hit requires the identical query
  vector (two independent 32-bit fingerprints of the raw float bytes plus
  the full (L, K) code compare) and bit-identical tau. Hits are then
  bit-identical to the estimate the original probe produced, so serving
  them adds zero q-error.
* ``reuse_tol > 0`` — LSH-keyed reuse: a hit requires the same bucket code
  in EVERY table (near-duplicate queries by LSH geometry) and a tau in the
  same multiplicative band (``floor(ln tau / ln(1 + reuse_tol))``), so a
  served estimate belongs to a query hashing identically under all L·K
  functions and a tau within a factor ``(1 + reuse_tol)`` — the knob
  trades hit rate against a bounded extra q-error (cardinality is
  monotone in tau, and full-code LSH collision bounds the query
  displacement relative to the bucket widths W).

Lookup is one vectorised compare over the entry axis; insertion is a
sequential ``fori_loop`` over the batch (entries written by earlier lanes
must be visible to later ones — duplicate keys in one flush overwrite in
place instead of double-filling). Eviction is textbook second-chance: the
hand sweeps from its last position, clearing ``ref`` on entries it passes,
and evicts the first entry whose ``ref`` is already clear.
"""
from __future__ import annotations

import math
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.cache.epochs import EpochState, ball_sums

_MULT = jnp.uint32(2654435761)


class EstimateCache(NamedTuple):
    # --- key table ---
    qcodes: jax.Array      # (S, L, K) int32 per-table bucket signatures
    qhash: jax.Array       # (S, 2) uint32 exact-query fingerprint
    tau_key: jax.Array     # (S,) int32 quantized tau band / exact tau bits
    # --- epoch snapshots (invalidation) ---
    snap_ball: jax.Array   # (S, L) int32 probed-ball populations
    snap_params: jax.Array # (S,) uint32
    probed_k: jax.Array    # (S, L) int32 — deepest ring the probe folded
    # --- value table ---
    est: jax.Array         # (S,) float32
    nvisited: jax.Array    # (S,) int32 sample count of the original probe
    # --- CLOCK ---
    valid: jax.Array       # (S,) bool
    ref: jax.Array         # (S,) bool second-chance bit
    hand: jax.Array        # () int32

    @property
    def size(self) -> int:
        return self.est.shape[0]


def init_cache(size: int, n_tables: int, n_funcs: int) -> EstimateCache:
    s = int(size)
    assert s > 0, size
    return EstimateCache(
        qcodes=jnp.zeros((s, n_tables, n_funcs), jnp.int32),
        qhash=jnp.zeros((s, 2), jnp.uint32),
        tau_key=jnp.zeros((s,), jnp.int32),
        snap_ball=jnp.zeros((s, n_tables), jnp.int32),
        snap_params=jnp.zeros((s,), jnp.uint32),
        probed_k=jnp.zeros((s, n_tables), jnp.int32),
        est=jnp.zeros((s,), jnp.float32),
        nvisited=jnp.zeros((s,), jnp.int32),
        valid=jnp.zeros((s,), bool),
        ref=jnp.zeros((s,), bool),
        hand=jnp.int32(0))


def tau_band(taus: jax.Array, reuse_tol: float) -> jax.Array:
    """Quantize taus into the cache's tau key. ``reuse_tol`` is static:
    0 keys on the exact float32 bits; > 0 on multiplicative log-bands of
    width ``(1 + reuse_tol)`` (see module docstring)."""
    taus = jnp.asarray(taus, jnp.float32)
    if reuse_tol <= 0.0:
        return jax.lax.bitcast_convert_type(taus, jnp.int32)
    inv = 1.0 / math.log1p(reuse_tol)
    return jnp.floor(jnp.log(jnp.maximum(taus, 1e-30)) * inv).astype(jnp.int32)


def query_hash(qs: jax.Array) -> jax.Array:
    """Two independent 32-bit fingerprints of the raw query bytes
    (..., d) -> (..., 2). Used only at ``reuse_tol == 0`` where a hit must
    be an exact repeat."""
    b = jax.lax.bitcast_convert_type(jnp.asarray(qs, jnp.float32),
                                     jnp.uint32)
    i = jnp.arange(b.shape[-1], dtype=jnp.uint32)
    h1 = jnp.sum(b * (2 * i + 1), axis=-1)
    h2 = jnp.sum((b ^ (b >> 16)) * (_MULT + 2 * i + 1), axis=-1)

    def mix(x):
        x = (x ^ (x >> 15)) * jnp.uint32(0x85EBCA6B)
        return x ^ (x >> 13)

    return jnp.stack([mix(h1), mix(h2)], axis=-1)


def _key_match(cache: EstimateCache, qc: jax.Array, qh: jax.Array,
               tk: jax.Array, match_qhash: bool) -> jax.Array:
    """(S,) bool — valid entries whose key equals one request's key."""
    m = cache.valid & (cache.tau_key == tk) & \
        jnp.all(cache.qcodes == qc[None], axis=(-2, -1))
    if match_qhash:
        m = m & jnp.all(cache.qhash == qh[None], axis=-1)
    return m


@partial(jax.jit, static_argnames=("match_qhash", "check_ingest"))
def lookup(cache: EstimateCache, ep: EpochState, bucket_codes: jax.Array,
           bucket_sizes: jax.Array, n_buckets: jax.Array,
           qcodes: jax.Array, qhash: jax.Array,
           tau_keys: jax.Array, live: jax.Array,
           match_qhash: bool = True, check_ingest: bool = True):
    """Batched lookup: (B, L, K) codes + (B, 2) fingerprints + (B,) tau
    keys -> ``(cache', est (B,), hit (B,), stale (B,))``.

    ``hit`` = key present AND the entry's epoch snapshot still matches —
    the params generation, and (``check_ingest``) the probed-ball
    population recomputed over the CURRENT bucket layout (epochs.py — the
    check is exact: populations are monotone and move iff an ingest landed
    in a probed ring). ``stale`` = key present but the check failed — the
    caller re-probes and the insert overwrites the entry in place.
    ``check_ingest=False`` (static) elides the ball recomputation
    entirely; callers may only pass it while NO ingest has happened since
    the cache was created (the coalescer tracks this on the host — the
    flag flips permanently on first ingest). ``live`` masks the
    batch-padding rows. Hits touch the CLOCK ``ref`` bit of their entry
    (second chance)."""

    def one(qc, qh, tk):
        m = _key_match(cache, qc, qh, tk, match_qhash)
        slot = jnp.argmax(m)
        key_hit = jnp.any(m)
        fresh = cache.snap_params[slot] == ep.params_epoch
        if check_ingest:
            ball = ball_sums(bucket_codes, bucket_sizes, n_buckets, qc,
                             cache.probed_k[slot])
            fresh = fresh & jnp.all(ball == cache.snap_ball[slot])
        return slot, key_hit & fresh, key_hit & ~fresh, cache.est[slot]

    slots, hit, stale, ests = jax.vmap(one)(qcodes, qhash, tau_keys)
    hit, stale = hit & live, stale & live
    ref = cache.ref.at[slots].max(hit)          # touch on hit only
    return cache._replace(ref=ref), ests, hit, stale


@partial(jax.jit, static_argnames=("match_qhash",))
def insert(cache: EstimateCache, ep: EpochState, bucket_codes: jax.Array,
           bucket_sizes: jax.Array, n_buckets: jax.Array,
           qcodes: jax.Array, qhash: jax.Array,
           tau_keys: jax.Array, ests: jax.Array, nvisited: jax.Array,
           probed_k: jax.Array, active: jax.Array,
           match_qhash: bool = True):
    """Write a probed batch back: for each active lane, overwrite the
    existing entry with the same key (stale refresh / duplicate-in-flush)
    or claim a CLOCK victim. Returns ``(cache', n_evicted)`` where
    ``n_evicted`` counts live entries displaced by new keys.

    ``match_qhash`` must mirror the LOOKUP key semantics (strict at
    ``reuse_tol=0``, code+band only above): if insert deduplicated more
    strictly than lookup matches, a stale near-duplicate entry would
    never be overwritten — lookup could keep finding (and re-flagging)
    the stale entry while refreshes pile up in other slots.

    The epoch snapshots (probed-ball populations) are taken HERE, against
    the bucket layout the probe ran under — the coalescer applies pending
    ingests before probing, so the snapshot is exact for the served
    estimate."""
    s = cache.size
    balls = ball_sums(bucket_codes, bucket_sizes, n_buckets, qcodes,
                      probed_k)                     # (B, L)
    pos = jnp.arange(s, dtype=jnp.int32)

    def body(i, carry):
        c, n_evicted = carry
        qc, qh, tk = qcodes[i], qhash[i], tau_keys[i]
        m = _key_match(c, qc, qh, tk, match_qhash)
        use_existing = jnp.any(m)
        # second-chance sweep from the hand
        order = (c.hand + 1 + pos) % s
        claimable = ~(c.ref[order] & c.valid[order])
        found = jnp.any(claimable)
        vpos = jnp.argmax(claimable)                # first claimable
        victim = order[vpos]
        passed = (pos < vpos) | ~found              # full sweep if none
        slot = jnp.where(use_existing, jnp.argmax(m), victim)
        do = active[i]
        do_evict = do & ~use_existing
        n_evicted += (do_evict & c.valid[victim]).astype(jnp.int32)
        # clear ref on every entry the hand swept past (eviction only)
        swept = jnp.where(do_evict,
                          c.ref.at[order].set(
                              jnp.where(passed, False, c.ref[order])),
                          c.ref)
        w = lambda a, v: a.at[slot].set(jnp.where(do, v, a[slot]))
        c = EstimateCache(
            qcodes=w(c.qcodes, qc), qhash=w(c.qhash, qh),
            tau_key=w(c.tau_key, tk),
            snap_ball=w(c.snap_ball, balls[i]),
            snap_params=w(c.snap_params, ep.params_epoch),
            probed_k=w(c.probed_k, probed_k[i]),
            est=w(c.est, ests[i]), nvisited=w(c.nvisited, nvisited[i]),
            valid=w(c.valid, jnp.bool_(True)),
            # fresh entries start with ref CLEAR — only a later hit arms
            # the second chance, so untouched keys are evicted before any
            # re-referenced one (a full-ref sweep would otherwise land on
            # whatever sits just past the hand, touched or not)
            ref=w(swept, jnp.bool_(False)),
            hand=jnp.where(do_evict, victim, c.hand))
        return c, n_evicted

    return jax.lax.fori_loop(0, qcodes.shape[0], body,
                             (cache, jnp.int32(0)))
