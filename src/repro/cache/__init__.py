"""Workload-aware estimate cache (DESIGN.md §12).

LSH-keyed reuse for the serving path: repeated / near-duplicate ``(q, tau)``
requests skip the probe → progressive-sampling → ADC pipeline entirely and
are served out of a fixed-capacity pure-array cache, kept correct under
dynamic ingest (paper §5) by per-bucket ingest-epoch counters.

* :mod:`repro.cache.epochs` — the invalidation signal: per
  (table, function, hashed code value) ingest counters bumped inside the
  recompile-free update step (DESIGN.md §10), snapshotted per cache entry,
  re-checked in O(rings) at lookup.
* :mod:`repro.cache.estimate_cache` — the jit-friendly store: key table of
  per-table LSH bucket signatures + quantized tau band, value table of
  estimates + sample stats, CLOCK/second-chance eviction. No Python dicts
  on the hot path.

Served through :class:`repro.serve.engine.CardinalityCoalescer`
(``cache_size=``/``reuse_tol=``) and
:class:`repro.serve.semantic.SemanticPlanner`.
"""
from repro.cache.epochs import (EpochState, ball_sums, ingest_bump,
                                init_epochs)
from repro.cache.estimate_cache import (EstimateCache, init_cache, insert,
                                        lookup, query_hash, tau_band)

__all__ = [
    "EpochState", "init_epochs", "ingest_bump", "ball_sums",
    "EstimateCache", "init_cache", "lookup", "insert", "query_hash",
    "tau_band",
]
