"""Batched serving engine: static-slot continuous batching over the dense
family's prefill/decode path, plus request coalescing for the estimator.

Small but production-shaped: a request queue, fixed decode slots, per-slot
positions, EOS/timeout retirement, and step-level batching (every decode
step advances all live slots in one jitted call). Used by
examples/serve_semantic.py with a reduced model; the dry-run proves the same
decode lowers at the assigned 32k/500k shapes.

:class:`CardinalityCoalescer` is the cardinality-side analogue (DESIGN.md
§9): concurrent ``(q, tau)`` estimation requests queue up and are flushed
through ONE jitted ``estimate_batch`` step, so the LSH hash matmul, PQ LUT
build and candidate scan are amortised across every in-flight request
instead of being re-dispatched per query.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import estimator as E
from repro.core.config import ProberConfig
from repro.models import get_family
from repro.models.base import ModelConfig


@dataclasses.dataclass
class CardRequest:
    """One pending cardinality-estimation request."""
    rid: int
    q: np.ndarray                 # (d,) query embedding
    tau: float
    est: Optional[float] = None   # filled by flush()


class CardinalityCoalescer:
    """Coalesces concurrent cardinality requests into one jitted step.

    ``submit`` enqueues; ``flush`` pads the pending batch up to the next
    power of two (so at most ``log2(max_batch) + 1`` batch shapes ever
    compile), runs a single ``estimate_batch`` over all of it, and returns
    ``{rid: estimate}``. Flush ``i`` derives its PRNG key as
    ``jax.random.fold_in(key, i)``, making a request's estimate a pure
    function of (key, flush index, position in batch) — deterministic and
    replayable for audit.
    """

    def __init__(self, state: E.ProberState, cfg: ProberConfig,
                 key: jax.Array, max_batch: int = 256):
        self.state = state
        self.cfg = cfg
        self.key = key
        # round up to a power of two: padding in flush() must never exceed
        # the configured cap, or the compile-shape bound above breaks
        self.max_batch = self._pad_to(max_batch)
        self.pending: list[CardRequest] = []
        self._next_rid = 0
        self._n_flushes = 0
        self._answered: dict[int, float] = {}   # auto-flush results not yet
                                                # returned by flush()

    def submit(self, q, tau) -> CardRequest:
        req = CardRequest(rid=self._next_rid, q=np.asarray(q),
                          tau=float(tau))
        self._next_rid += 1
        self.pending.append(req)
        if len(self.pending) >= self.max_batch:
            self._answered.update(self._drain())
        return req

    @staticmethod
    def _pad_to(n: int) -> int:
        p = 1
        while p < n:
            p *= 2
        return p

    def flush(self) -> dict[int, float]:
        """Jitted estimate_batch steps (max_batch each) until nothing is
        pending; returns every answered {rid: estimate} not yet returned —
        including requests already answered by a submit()-triggered
        auto-flush."""
        out = self._answered
        self._answered = {}
        out.update(self._drain())
        return out

    def _drain(self) -> dict[int, float]:
        out: dict[int, float] = {}
        while self.pending:
            batch, self.pending = self.pending[:self.max_batch], \
                self.pending[self.max_batch:]
            n = len(batch)
            p = self._pad_to(n)
            d = batch[0].q.shape[-1]
            qs = np.zeros((p, d), np.float32)
            taus = np.zeros((p,), np.float32)
            for i, r in enumerate(batch):
                qs[i], taus[i] = r.q, r.tau
            key = jax.random.fold_in(self.key, self._n_flushes)
            self._n_flushes += 1
            ests = np.asarray(E.estimate_batch(
                self.state, jnp.asarray(qs), jnp.asarray(taus),
                self.cfg, key))
            for i, r in enumerate(batch):
                r.est = float(ests[i])
                out[r.rid] = r.est
        return out


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (S,) int32
    max_new: int = 16
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, batch_slots: int = 4,
                 max_len: int = 256, eos: int = 1):
        assert cfg.family in ("dense",), "engine drives the dense family"
        self.cfg = cfg
        self.fam = get_family(cfg)
        self.params = params
        self.slots = batch_slots
        self.max_len = max_len
        self.eos = eos
        self.cache = self.fam.init_cache(cfg, batch_slots, max_len)
        self.live: list[Optional[Request]] = [None] * batch_slots
        self.queue: list[Request] = []
        self._decode = jax.jit(
            lambda p, c, t: self.fam.decode_step(p, c, t, cfg))
        self._prefill_one = jax.jit(
            lambda p, b: self.fam.prefill(p, b, cfg, max_len=max_len))

    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for i in range(self.slots):
            if self.live[i] is None and self.queue:
                req = self.queue.pop(0)
                cache_i, logits = self._prefill_one(
                    self.params, {"tokens": jnp.asarray(req.prompt)[None, :]})
                # copy the single-sequence cache into slot i
                self.cache = {
                    "k": self.cache["k"].at[:, i].set(cache_i["k"][:, 0]),
                    "v": self.cache["v"].at[:, i].set(cache_i["v"][:, 0]),
                    "pos": jnp.maximum(self.cache["pos"], cache_i["pos"]),
                }
                req.out.append(int(jnp.argmax(logits[0])))
                self.live[i] = req

    def step(self):
        """One decode step for every live slot."""
        self._admit()
        if not any(self.live):
            return False
        tokens = jnp.asarray(
            [r.out[-1] if r else 0 for r in self.live], jnp.int32)
        logits, self.cache = self._decode(self.params, self.cache, tokens)
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        for i, req in enumerate(self.live):
            if req is None:
                continue
            tok = int(nxt[i])
            req.out.append(tok)
            if tok == self.eos or len(req.out) >= req.max_new or \
                    int(self.cache["pos"]) >= self.max_len - 1:
                req.done = True
                self.live[i] = None
        return True

    def run(self, max_steps: int = 512) -> list[Request]:
        finished: list[Request] = []
        seen: set[int] = set()
        all_reqs = list(self.queue)
        for _ in range(max_steps):
            if not self.step() and not self.queue:
                break
        for r in all_reqs:
            if r.done and r.rid not in seen:
                finished.append(r)
                seen.add(r.rid)
        return finished
