"""Batched serving engine: static-slot continuous batching over the dense
family's prefill/decode path, plus request coalescing for the estimator.

Small but production-shaped: a request queue, fixed decode slots, per-slot
positions, EOS/timeout retirement, and step-level batching (every decode
step advances all live slots in one jitted call). Used by
examples/serve_semantic.py with a reduced model; the dry-run proves the same
decode lowers at the assigned 32k/500k shapes.

:class:`CardinalityCoalescer` is the cardinality-side analogue (DESIGN.md
§9): concurrent ``(q, tau)`` estimation requests queue up and are flushed
through ONE jitted ``estimate_batch`` step, so the LSH hash matmul, PQ LUT
build and candidate scan are amortised across every in-flight request
instead of being re-dispatched per query.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import estimator as E, updates
from repro.core.config import ProberConfig
from repro.models import get_family
from repro.models.base import ModelConfig


@dataclasses.dataclass
class CardRequest:
    """One pending cardinality-estimation request."""
    rid: int
    q: np.ndarray                 # (d,) query embedding
    tau: float
    est: Optional[float] = None   # filled by flush()


class CardinalityCoalescer:
    """Coalesces concurrent cardinality requests into one jitted step.

    ``submit`` enqueues; ``flush`` pads the pending batch up to the next
    power of two (so at most ``log2(max_batch) + 1`` batch shapes ever
    compile), runs a single ``estimate_batch`` over all of it, and returns
    ``{rid: estimate}``. Flush ``i`` derives its PRNG key as
    ``jax.random.fold_in(key, i)``, making a request's estimate a pure
    function of (key, flush index, position in batch) — deterministic and
    replayable for audit.

    Flushes run under the skew-resilient compacting scheduler (DESIGN.md
    §11, ``cfg.lane_block``; engages once a flush spans more than
    ``cfg.lane_tile`` lanes): a coalesced batch mixes independent clients'
    (q, tau) requests, so per-lane work is naturally skewed, and compaction
    keeps one slow request from billing its slab work to every finished
    lane in the flush. The compacting loop is shape-static, so it adds no
    per-flush recompiles (tested in tests/test_compact.py).

    With ``mesh`` (DESIGN.md §4) the coalescer serves off a SHARDED index
    (the state ``distributed.build_sharded`` returns): flushes run the
    distributed ``estimate_sharded`` with the chosen stopping ``mode``
    (``"local"`` per-shard ε-stopping + psum, or ``"sync"`` pooled global
    Chernoff statistics), and :meth:`ingest` routes new points through the
    round-robin sharded recompile-free update step, tracking per-shard live
    counts on the host so dispatch stays async.
    """

    def __init__(self, state: E.ProberState, cfg: ProberConfig,
                 key: jax.Array, max_batch: int = 256,
                 mesh=None, data_axes=("data",), mode: str = "local"):
        assert mode in ("local", "sync"), mode
        self.mesh = mesh
        self.data_axes = tuple(data_axes)
        self.mode = mode
        self.state = state              # property: also syncs _n_valid
        self.cfg = cfg
        self.key = key
        # round up to a power of two: padding in flush() must never exceed
        # the configured cap, or the compile-shape bound above breaks
        self.max_batch = updates.next_pow2(max_batch)
        self.pending: list[CardRequest] = []
        self._next_rid = 0
        self._n_flushes = 0
        self._answered: dict[int, float] = {}   # auto-flush results not yet
                                                # returned by flush()
        self._ingest_buf: Optional[np.ndarray] = None   # pending new points

    @property
    def state(self) -> E.ProberState:
        return self._state

    @state.setter
    def state(self, st: E.ProberState):
        # re-reads the live count whenever the state is swapped from outside;
        # the internal ingest loop bypasses this (tracking the count on the
        # host) so chunk dispatch never blocks on a device_get
        self._state = st
        nv = jax.device_get(st.index.n_valid)
        # sharded states carry one live count per shard
        self._n_valid = np.asarray(nv) if self.mesh is not None else int(nv)

    def submit(self, q, tau) -> CardRequest:
        req = CardRequest(rid=self._next_rid, q=np.asarray(q),
                          tau=float(tau))
        self._next_rid += 1
        self.pending.append(req)
        if len(self.pending) >= self.max_batch:
            self._answered.update(self._drain())
        return req

    # ------------------------------------------------- dynamic ingest -----
    def ingest(self, x_new) -> int:
        """Queue new corpus points (paper §5) for the serving index.

        Points are buffered and applied through the recompile-free
        capacity-padded update step (DESIGN.md §10) in fixed chunks of
        ``cfg.ingest_chunk`` — eagerly once a full chunk accumulates, and
        always before the next estimate flush, so every estimate reflects
        all points ingested before it. Returns the number still buffered.
        """
        x = np.asarray(x_new, np.float32)
        if x.ndim == 1:
            x = x[None]
        self._ingest_buf = x if self._ingest_buf is None else \
            np.concatenate([self._ingest_buf, x], axis=0)
        chunk = self.cfg.ingest_chunk
        while self._ingest_buf is not None and len(self._ingest_buf) >= chunk:
            self._apply_ingest_chunk(chunk)
        return 0 if self._ingest_buf is None else len(self._ingest_buf)

    def apply_ingest(self):
        """Drain the ingest buffer completely (the final partial chunk is
        padded to a power of two inside estimator.update)."""
        chunk = self.cfg.ingest_chunk
        while self._ingest_buf is not None and len(self._ingest_buf) > 0:
            self._apply_ingest_chunk(min(chunk, len(self._ingest_buf)))

    def _apply_ingest_chunk(self, k: int):
        buf = self._ingest_buf
        part, rest = buf[:k], buf[k:]
        self._ingest_buf = rest if len(rest) else None
        if self.mesh is not None:
            from repro.core import distributed as D
            self._state, self._n_valid = D.update_sharded(
                self._state, part, self.cfg, self.mesh,
                data_axes=self.data_axes, n_valid=self._n_valid)
            return
        self._state = E.update(self._state, jnp.asarray(part), self.cfg,
                               n_valid=self._n_valid)
        self._n_valid += len(part)

    def flush(self) -> dict[int, float]:
        """Apply pending ingests, then run jitted estimate_batch steps
        (max_batch each) until nothing is pending; returns every answered
        {rid: estimate} not yet returned — including requests already
        answered by a submit()-triggered auto-flush."""
        out = self._answered
        self._answered = {}
        out.update(self._drain())
        return out

    def _drain(self) -> dict[int, float]:
        self.apply_ingest()          # estimates see every prior ingest()
        out: dict[int, float] = {}
        while self.pending:
            batch, self.pending = self.pending[:self.max_batch], \
                self.pending[self.max_batch:]
            n = len(batch)
            p = updates.next_pow2(n)
            d = batch[0].q.shape[-1]
            qs = np.zeros((p, d), np.float32)
            taus = np.zeros((p,), np.float32)
            for i, r in enumerate(batch):
                qs[i], taus[i] = r.q, r.tau
            key = jax.random.fold_in(self.key, self._n_flushes)
            self._n_flushes += 1
            if self.mesh is not None:
                from repro.core import distributed as D
                ests = np.asarray(D.estimate_sharded(
                    self.state, jnp.asarray(qs), jnp.asarray(taus),
                    self.cfg, key, self.mesh, data_axes=self.data_axes,
                    mode=self.mode))
            else:
                ests = np.asarray(E.estimate_batch(
                    self.state, jnp.asarray(qs), jnp.asarray(taus),
                    self.cfg, key))
            for i, r in enumerate(batch):
                r.est = float(ests[i])
                out[r.rid] = r.est
        return out


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (S,) int32
    max_new: int = 16
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, batch_slots: int = 4,
                 max_len: int = 256, eos: int = 1):
        assert cfg.family in ("dense",), "engine drives the dense family"
        self.cfg = cfg
        self.fam = get_family(cfg)
        self.params = params
        self.slots = batch_slots
        self.max_len = max_len
        self.eos = eos
        self.cache = self.fam.init_cache(cfg, batch_slots, max_len)
        # per-slot decode positions: slots prefill at different times with
        # different prompt lengths, so a shared scalar position would make a
        # slot admitted after a longer request write its KV at the wrong row
        # and retire early (RoPE phase and the causal mask also depend on it)
        self.cache["pos"] = jnp.zeros((batch_slots,), jnp.int32)
        self.live: list[Optional[Request]] = [None] * batch_slots
        self.queue: list[Request] = []
        self.finished: list[Request] = []     # retired but not yet returned
        self._decode = jax.jit(
            lambda p, c, t: self.fam.decode_step(p, c, t, cfg))
        self._prefill_one = jax.jit(
            lambda p, b: self.fam.prefill(p, b, cfg, max_len=max_len))

    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for i in range(self.slots):
            if self.live[i] is None and self.queue:
                req = self.queue.pop(0)
                cache_i, logits = self._prefill_one(
                    self.params, {"tokens": jnp.asarray(req.prompt)[None, :]})
                # copy the single-sequence cache into slot i; position is
                # per-slot — only slot i takes the new request's length
                self.cache = {
                    "k": self.cache["k"].at[:, i].set(cache_i["k"][:, 0]),
                    "v": self.cache["v"].at[:, i].set(cache_i["v"][:, 0]),
                    "pos": self.cache["pos"].at[i].set(cache_i["pos"]),
                }
                req.out.append(int(jnp.argmax(logits[0])))
                self.live[i] = req

    def step(self):
        """One decode step for every live slot."""
        self._admit()
        if not any(self.live):
            return False
        tokens = jnp.asarray(
            [r.out[-1] if r else 0 for r in self.live], jnp.int32)
        logits, self.cache = self._decode(self.params, self.cache, tokens)
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        pos = np.asarray(self.cache["pos"])       # already advanced by decode
        for i, req in enumerate(self.live):
            if req is None:
                continue
            tok = int(nxt[i])
            req.out.append(tok)
            if tok == self.eos or len(req.out) >= req.max_new or \
                    int(pos[i]) >= self.max_len - 1:
                req.done = True
                self.live[i] = None
                self.finished.append(req)
        return True

    def run(self, max_steps: int = 512) -> list[Request]:
        """Drive decode steps until idle; returns every request finished
        during the run — tracked as slots retire, so requests that were
        already admitted to a slot before run() or submitted while it is
        stepping are returned too (a queue snapshot at entry would miss
        both)."""
        for _ in range(max_steps):
            if not self.step() and not self.queue:
                break
        finished, self.finished = self.finished, []
        return finished
