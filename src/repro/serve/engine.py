"""Batched serving engine: static-slot continuous batching over the dense
family's prefill/decode path, plus request coalescing for the estimator.

Small but production-shaped: a request queue, fixed decode slots, per-slot
positions, EOS/timeout retirement, and step-level batching (every decode
step advances all live slots in one jitted call). Used by
examples/serve_semantic.py with a reduced model; the dry-run proves the same
decode lowers at the assigned 32k/500k shapes.

:class:`CardinalityCoalescer` is the cardinality-side analogue (DESIGN.md
§9): concurrent ``(q, tau)`` estimation requests queue up and are flushed
through ONE jitted ``estimate_batch`` step, so the LSH hash matmul, PQ LUT
build and candidate scan are amortised across every in-flight request
instead of being re-dispatched per query.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.cache import estimate_cache as C
from repro.core import estimator as E, lsh, updates
from repro.core.config import ProberConfig
from repro.models import get_family
from repro.models.base import ModelConfig


@dataclasses.dataclass
class CardRequest:
    """One pending cardinality-estimation request."""
    rid: int
    q: np.ndarray                 # (d,) query embedding
    tau: float
    est: Optional[float] = None   # filled by flush()
    provenance: Optional[str] = None   # "probe" | "hit" | "stale-refresh"
                                  # — how flush() produced the estimate
    probed_k: Optional[np.ndarray] = None   # (L,) deepest ring folded per
                                  # table when this request was PROBED
                                  # (None on cache hits — the entry's
                                  # original probe set the rings)
    nvisited: Optional[int] = None     # samples the probe drew (audit)


class CardResult(float):
    """A flush() result value: a float (the estimate) carrying per-request
    provenance so callers can audit what they were served — a fresh probe,
    a cache hit, or a probe that refreshed a stale entry. Compares/serialises
    exactly like the plain float it replaced."""
    provenance: str

    def __new__(cls, est: float, provenance: str = "probe"):
        self = super().__new__(cls, est)
        self.provenance = provenance
        return self


class CardinalityCoalescer:
    """Coalesces concurrent cardinality requests into one jitted step.

    ``submit`` enqueues; ``flush`` pads the pending batch up to the next
    power of two (so at most ``log2(max_batch) + 1`` batch shapes ever
    compile), runs a single ``estimate_batch`` over all of it, and returns
    ``{rid: estimate}``. Flush ``i`` derives its PRNG key as
    ``jax.random.fold_in(key, i)``, making a request's estimate a pure
    function of (key, flush index, position in batch) — deterministic and
    replayable for audit.

    Flushes run under the skew-resilient compacting scheduler (DESIGN.md
    §11, ``cfg.lane_block``; engages once a flush spans more than
    ``cfg.lane_tile`` lanes): a coalesced batch mixes independent clients'
    (q, tau) requests, so per-lane work is naturally skewed, and compaction
    keeps one slow request from billing its slab work to every finished
    lane in the flush. The compacting loop is shape-static, so it adds no
    per-flush recompiles (tested in tests/test_compact.py).

    With ``mesh`` (DESIGN.md §4) the coalescer serves off a SHARDED index
    (the state ``distributed.build_sharded`` returns): flushes run the
    distributed ``estimate_sharded`` with the chosen stopping ``mode``
    (``"local"`` per-shard ε-stopping + psum, or ``"sync"`` pooled global
    Chernoff statistics), and :meth:`ingest` routes new points through the
    round-robin sharded recompile-free update step, tracking per-shard live
    counts on the host so dispatch stays async.

    With ``cache_size > 0`` (DESIGN.md §12) each flush first partitions the
    batch against the workload-aware estimate cache: hits are served out of
    the fixed-capacity array cache, only the MISS lanes are probed (a
    smaller ``estimate_batch`` — fewer lanes in means fewer compacted tiles
    run under the §11 scheduler), and fresh results are written back with
    their ingest-epoch snapshots. A hit is served only while no ingest has
    touched any bucket the original probe visited (the O(rings) epoch
    check); ``reuse_tol`` widens the key from exact-repeat to LSH
    near-duplicate matching (see repro/cache). Local (unsharded) serving
    only — the cache keys on this process's index geometry. Per-request
    provenance lands in :class:`CardRequest`/:class:`CardResult`; hit /
    miss / stale / evict counters accumulate in :attr:`cache_stats`.
    """

    def __init__(self, state: E.ProberState, cfg: ProberConfig,
                 key: jax.Array, max_batch: int = 256,
                 mesh=None, data_axes=("data",), mode: str = "local",
                 cache_size: int = 0, reuse_tol: float = 0.0):
        assert mode in ("local", "sync"), mode
        assert cache_size == 0 or mesh is None, \
            "the estimate cache serves the local path only (DESIGN.md §12)"
        self.mesh = mesh
        self.data_axes = tuple(data_axes)
        self.mode = mode
        self.cfg = cfg
        self.reuse_tol = float(reuse_tol)
        self._cache = C.init_cache(cache_size, cfg.n_tables, cfg.n_funcs) \
            if cache_size > 0 else None
        self.cache_stats = {"hits": 0, "misses": 0, "stale": 0, "evicts": 0,
                            "lookups": 0}
        # host-tracked: False until the first ingest (or external state
        # swap) — lets lookup() statically elide the ball-sum recompute
        # while the corpus is provably unchanged (repro/cache/epochs.py)
        self._check_ingest = False
        self._hash = jax.jit(
            lambda params, qs: lsh.hash_point(params, qs, cfg.n_tables))
        self.state = state              # property: also syncs _n_valid
        self._check_ingest = False      # the swap bump above is moot while
                                        # the cache is still empty
        self.key = key
        # round up to a power of two: padding in flush() must never exceed
        # the configured cap, or the compile-shape bound above breaks
        self.max_batch = updates.next_pow2(max_batch)
        self.pending: list[CardRequest] = []
        self._next_rid = 0
        self._n_flushes = 0
        self._answered: dict[int, float] = {}   # auto-flush results not yet
                                                # returned by flush()
        self._ingest_buf: Optional[np.ndarray] = None   # pending new points

    @property
    def state(self) -> E.ProberState:
        return self._state

    @state.setter
    def state(self, st: E.ProberState):
        # re-reads the live count whenever the state is swapped from outside;
        # the internal ingest loop bypasses this (tracking the count on the
        # host) so chunk dispatch never blocks on a device_get
        if self._cache is not None:
            if st.epochs is None:
                st = E.attach_epochs(st)
            # an externally swapped state may hold ARBITRARY new data whose
            # ingests this coalescer never saw — retire the whole cache
            # generation rather than risk a stale hit against it
            st = st._replace(epochs=st.epochs._replace(
                params_epoch=st.epochs.params_epoch + jnp.uint32(1)))
            self._check_ingest = True
        self._state = st
        nv = jax.device_get(st.index.n_valid)
        # sharded states carry one live count per shard
        self._n_valid = np.asarray(nv) if self.mesh is not None else int(nv)

    def submit(self, q, tau) -> CardRequest:
        req = CardRequest(rid=self._next_rid, q=np.asarray(q),
                          tau=float(tau))
        self._next_rid += 1
        self.pending.append(req)
        if len(self.pending) >= self.max_batch:
            self._answered.update(self._drain())
        return req

    # ------------------------------------------------- dynamic ingest -----
    def ingest(self, x_new) -> int:
        """Queue new corpus points (paper §5) for the serving index.

        Points are buffered and applied through the recompile-free
        capacity-padded update step (DESIGN.md §10) in fixed chunks of
        ``cfg.ingest_chunk`` — eagerly once a full chunk accumulates, and
        always before the next estimate flush, so every estimate reflects
        all points ingested before it. Returns the number still buffered.
        """
        x = np.asarray(x_new, np.float32)
        if x.ndim == 1:
            x = x[None]
        self._ingest_buf = x if self._ingest_buf is None else \
            np.concatenate([self._ingest_buf, x], axis=0)
        chunk = self.cfg.ingest_chunk
        while self._ingest_buf is not None and len(self._ingest_buf) >= chunk:
            self._apply_ingest_chunk(chunk)
        return 0 if self._ingest_buf is None else len(self._ingest_buf)

    def apply_ingest(self):
        """Drain the ingest buffer completely (the final partial chunk is
        padded to a power of two inside estimator.update)."""
        chunk = self.cfg.ingest_chunk
        while self._ingest_buf is not None and len(self._ingest_buf) > 0:
            self._apply_ingest_chunk(min(chunk, len(self._ingest_buf)))

    def _apply_ingest_chunk(self, k: int):
        self._check_ingest = True       # lookups must re-check ball sums
        buf = self._ingest_buf
        part, rest = buf[:k], buf[k:]
        self._ingest_buf = rest if len(rest) else None
        if self.mesh is not None:
            from repro.core import distributed as D
            self._state, self._n_valid = D.update_sharded(
                self._state, part, self.cfg, self.mesh,
                data_axes=self.data_axes, n_valid=self._n_valid)
            return
        self._state = E.update(self._state, jnp.asarray(part), self.cfg,
                               n_valid=self._n_valid)
        self._n_valid += len(part)

    def flush(self) -> dict[int, float]:
        """Apply pending ingests, then run jitted estimate_batch steps
        (max_batch each) until nothing is pending; returns every answered
        {rid: estimate} not yet returned — including requests already
        answered by a submit()-triggered auto-flush. Values are
        :class:`CardResult` — floats that also carry per-request
        ``provenance`` (``"probe"`` | ``"hit"`` | ``"stale-refresh"``) so
        callers can audit whether an estimate came off a fresh probe or
        the estimate cache."""
        out = self._answered
        self._answered = {}
        out.update(self._drain())
        return out

    def _drain(self) -> dict[int, float]:
        self.apply_ingest()          # estimates see every prior ingest()
        out: dict[int, float] = {}
        while self.pending:
            batch, self.pending = self.pending[:self.max_batch], \
                self.pending[self.max_batch:]
            n = len(batch)
            p = updates.next_pow2(n)
            d = batch[0].q.shape[-1]
            qs = np.zeros((p, d), np.float32)
            taus = np.zeros((p,), np.float32)
            for i, r in enumerate(batch):
                qs[i], taus[i] = r.q, r.tau
            key = jax.random.fold_in(self.key, self._n_flushes)
            self._n_flushes += 1
            if self._cache is not None:
                ests, prov, pks, nvs = self._flush_cached(qs, taus, n, key)
                for i, r in enumerate(batch):
                    r.probed_k, r.nvisited = pks[i], nvs[i]
            elif self.mesh is not None:
                from repro.core import distributed as D
                ests = np.asarray(D.estimate_sharded(
                    self.state, jnp.asarray(qs), jnp.asarray(taus),
                    self.cfg, key, self.mesh, data_axes=self.data_axes,
                    mode=self.mode))
                prov = ["probe"] * n
            else:
                ests = np.asarray(E.estimate_batch(
                    self.state, jnp.asarray(qs), jnp.asarray(taus),
                    self.cfg, key))
                prov = ["probe"] * n
            for i, r in enumerate(batch):
                r.est = float(ests[i])
                r.provenance = prov[i]
                out[r.rid] = CardResult(r.est, prov[i])
        return out

    def _flush_cached(self, qs: np.ndarray, taus: np.ndarray, n: int,
                      key: jax.Array):
        """One flush through the estimate cache (DESIGN.md §12): look every
        request up, probe ONLY the miss lanes (padded to a power of two so
        the §11 compacting scheduler sees at most log2(max_batch) batch
        shapes), write fresh results back with their epoch snapshots, and
        merge. Returns ``(ests (n,), provenance (n,), probed_k (n,),
        nvisited (n,))`` — the latter two per-request audit stats (None
        for hits, whose rings were set by the entry's original probe)."""
        st = self._state
        strict = self.reuse_tol <= 0.0
        jqs = jnp.asarray(qs)
        qcodes = self._hash(st.index.params, jqs)
        qhash = C.query_hash(jqs)
        tkeys = C.tau_band(jnp.asarray(taus), self.reuse_tol)
        live = jnp.arange(qs.shape[0]) < n
        self._cache, c_est, hit, stale = C.lookup(
            self._cache, st.epochs, st.index.bucket_codes,
            st.index.bucket_sizes, st.index.n_buckets, qcodes, qhash,
            tkeys, live, match_qhash=strict,
            check_ingest=self._check_ingest)
        hit = np.asarray(hit)[:n]
        stale = np.asarray(stale)[:n]
        ests = np.asarray(c_est)[:n].copy()
        miss = np.nonzero(~hit)[0]
        self.cache_stats["lookups"] += n
        self.cache_stats["hits"] += int(hit.sum())
        self.cache_stats["misses"] += len(miss)
        self.cache_stats["stale"] += int(stale.sum())
        prov = ["hit" if hit[i] else
                ("stale-refresh" if stale[i] else "probe")
                for i in range(n)]
        pks: list = [None] * n
        nvs: list = [None] * n
        if len(miss):
            pm = updates.next_pow2(len(miss))
            qs_m = np.zeros((pm, qs.shape[1]), np.float32)
            taus_m = np.zeros((pm,), np.float32)
            qs_m[:len(miss)], taus_m[:len(miss)] = qs[miss], taus[miss]
            jqs_m, jtaus_m = jnp.asarray(qs_m), jnp.asarray(taus_m)
            ests_m, probed_k, nvis = E.estimate_batch_stats(
                st, jqs_m, jtaus_m, self.cfg, key)
            active = jnp.arange(pm) < len(miss)
            # keys for the write-back: gather the rows already computed for
            # the full-batch lookup (no second hash matmul / fingerprint
            # pass); rows past len(miss) are padding and inactive
            mrows = jnp.asarray(np.pad(miss, (0, pm - len(miss))))
            self._cache, n_evict = C.insert(
                self._cache, st.epochs, st.index.bucket_codes,
                st.index.bucket_sizes, st.index.n_buckets,
                qcodes[mrows], qhash[mrows], tkeys[mrows],
                ests_m, nvis, probed_k, active, match_qhash=strict)
            self.cache_stats["evicts"] += int(n_evict)
            ests[miss] = np.asarray(ests_m)[:len(miss)]
            pk_np, nv_np = np.asarray(probed_k), np.asarray(nvis)
            for j, i in enumerate(miss):
                pks[i], nvs[i] = pk_np[j], int(nv_np[j])
        return ests, prov, pks, nvs


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (S,) int32
    max_new: int = 16
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, batch_slots: int = 4,
                 max_len: int = 256, eos: int = 1):
        assert cfg.family in ("dense",), "engine drives the dense family"
        self.cfg = cfg
        self.fam = get_family(cfg)
        self.params = params
        self.slots = batch_slots
        self.max_len = max_len
        self.eos = eos
        self.cache = self.fam.init_cache(cfg, batch_slots, max_len)
        # per-slot decode positions: slots prefill at different times with
        # different prompt lengths, so a shared scalar position would make a
        # slot admitted after a longer request write its KV at the wrong row
        # and retire early (RoPE phase and the causal mask also depend on it)
        self.cache["pos"] = jnp.zeros((batch_slots,), jnp.int32)
        self.live: list[Optional[Request]] = [None] * batch_slots
        self.queue: list[Request] = []
        self.finished: list[Request] = []     # retired but not yet returned
        self._decode = jax.jit(
            lambda p, c, t: self.fam.decode_step(p, c, t, cfg))
        self._prefill_one = jax.jit(
            lambda p, b: self.fam.prefill(p, b, cfg, max_len=max_len))

    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for i in range(self.slots):
            if self.live[i] is None and self.queue:
                req = self.queue.pop(0)
                cache_i, logits = self._prefill_one(
                    self.params, {"tokens": jnp.asarray(req.prompt)[None, :]})
                # copy the single-sequence cache into slot i; position is
                # per-slot — only slot i takes the new request's length
                self.cache = {
                    "k": self.cache["k"].at[:, i].set(cache_i["k"][:, 0]),
                    "v": self.cache["v"].at[:, i].set(cache_i["v"][:, 0]),
                    "pos": self.cache["pos"].at[i].set(cache_i["pos"]),
                }
                req.out.append(int(jnp.argmax(logits[0])))
                self.live[i] = req

    def step(self):
        """One decode step for every live slot."""
        self._admit()
        if not any(self.live):
            return False
        tokens = jnp.asarray(
            [r.out[-1] if r else 0 for r in self.live], jnp.int32)
        logits, self.cache = self._decode(self.params, self.cache, tokens)
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        pos = np.asarray(self.cache["pos"])       # already advanced by decode
        for i, req in enumerate(self.live):
            if req is None:
                continue
            tok = int(nxt[i])
            req.out.append(tok)
            if tok == self.eos or len(req.out) >= req.max_new or \
                    int(pos[i]) >= self.max_len - 1:
                req.done = True
                self.live[i] = None
                self.finished.append(req)
        return True

    def run(self, max_steps: int = 512) -> list[Request]:
        """Drive decode steps until idle; returns every request finished
        during the run — tracked as slots retire, so requests that were
        already admitted to a slot before run() or submitted while it is
        stepping are returned too (a queue snapshot at entry would miss
        both)."""
        for _ in range(max_steps):
            if not self.step() and not self.queue:
                break
        finished, self.finished = self.finished, []
        return finished
