"""Batched serving engine: static-slot continuous batching over the dense
family's prefill/decode path.

Small but production-shaped: a request queue, fixed decode slots, per-slot
positions, EOS/timeout retirement, and step-level batching (every decode
step advances all live slots in one jitted call). Used by
examples/serve_semantic.py with a reduced model; the dry-run proves the same
decode lowers at the assigned 32k/500k shapes.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import get_family
from repro.models.base import ModelConfig


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (S,) int32
    max_new: int = 16
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, batch_slots: int = 4,
                 max_len: int = 256, eos: int = 1):
        assert cfg.family in ("dense",), "engine drives the dense family"
        self.cfg = cfg
        self.fam = get_family(cfg)
        self.params = params
        self.slots = batch_slots
        self.max_len = max_len
        self.eos = eos
        self.cache = self.fam.init_cache(cfg, batch_slots, max_len)
        self.live: list[Optional[Request]] = [None] * batch_slots
        self.queue: list[Request] = []
        self._decode = jax.jit(
            lambda p, c, t: self.fam.decode_step(p, c, t, cfg))
        self._prefill_one = jax.jit(
            lambda p, b: self.fam.prefill(p, b, cfg, max_len=max_len))

    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for i in range(self.slots):
            if self.live[i] is None and self.queue:
                req = self.queue.pop(0)
                cache_i, logits = self._prefill_one(
                    self.params, {"tokens": jnp.asarray(req.prompt)[None, :]})
                # copy the single-sequence cache into slot i
                self.cache = {
                    "k": self.cache["k"].at[:, i].set(cache_i["k"][:, 0]),
                    "v": self.cache["v"].at[:, i].set(cache_i["v"][:, 0]),
                    "pos": jnp.maximum(self.cache["pos"], cache_i["pos"]),
                }
                req.out.append(int(jnp.argmax(logits[0])))
                self.live[i] = req

    def step(self):
        """One decode step for every live slot."""
        self._admit()
        if not any(self.live):
            return False
        tokens = jnp.asarray(
            [r.out[-1] if r else 0 for r in self.live], jnp.int32)
        logits, self.cache = self._decode(self.params, self.cache, tokens)
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        for i, req in enumerate(self.live):
            if req is None:
                continue
            tok = int(nxt[i])
            req.out.append(tok)
            if tok == self.eos or len(req.out) >= req.max_new or \
                    int(self.cache["pos"]) >= self.max_len - 1:
                req.done = True
                self.live[i] = None
        return True

    def run(self, max_steps: int = 512) -> list[Request]:
        finished: list[Request] = []
        seen: set[int] = set()
        all_reqs = list(self.queue)
        for _ in range(max_steps):
            if not self.step() and not self.queue:
                break
        for r in all_reqs:
            if r.done and r.rid not in seen:
                finished.append(r)
                seen.add(r.rid)
        return finished
