"""Serve-step factories: prefill (full forward, last-position logits) and
decode (single token against a KV cache / recurrent state)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import get_family
from repro.models.base import ModelConfig
from repro.train.step import _with_unroll


def make_prefill_step(cfg: ModelConfig, unroll_layers: bool = False):
    """prefill(params, batch) -> last-position logits (B, V).

    For whisper this is the encoder pass + cross-KV precompute + one decoder
    step worth of logits (the realistic prefill work for enc-dec serving).
    """
    fam = get_family(cfg)

    def prefill(params, batch):
        if cfg.family == "whisper":
            enc_out = fam.encode(params, batch["frames"], cfg)
            b = enc_out.shape[0]
            cache = fam.init_cache(cfg, b, 8, enc_len=enc_out.shape[1])
            cache = fam.prefill_cross(params, enc_out, cache, cfg)
            bos = jnp.zeros((b,), jnp.int32)
            logits, cache = fam.decode_step(params, cache, bos, cfg)
            return logits
        logits = fam.forward(params, batch, cfg)
        return logits[:, -1]

    return _with_unroll(prefill, unroll_layers)


def make_decode_step(cfg: ModelConfig, unroll_layers: bool = False):
    """decode(params, cache, tokens (B,)) -> (logits (B, V), new cache)."""
    fam = get_family(cfg)

    def decode(params, cache, tokens):
        return fam.decode_step(params, cache, tokens, cfg)

    return _with_unroll(decode, unroll_layers)
