"""Semantic-operator planner — the paper's motivating application (§1):
"estimate the number of interactions with the LLM without actual execution".

A semantic operator (e.g. ``SEM_JOIN docs ON similarity(q) <= tau`` followed
by an LLM call per match) needs the match cardinality BEFORE execution to
pick a plan: batch size, slot count, whether to run at all (cost ceilings).
The planner wraps the Dynamic Prober over the operator's embedding corpus and
converts cardinality estimates into an execution plan for the serving engine.
"""
from __future__ import annotations

import dataclasses
import math

import jax

from repro.core import estimator as E
from repro.core.config import ProberConfig


@dataclasses.dataclass
class OperatorPlan:
    est_matches: float
    llm_calls: int            # calls the plan will schedule
    batch_slots: int          # engine slots to provision
    n_batches: int
    action: str               # "execute" | "fallback_exact" | "refuse"
    reason: str = ""


class SemanticPlanner:
    def __init__(self, corpus_embeddings, cfg: ProberConfig, key,
                 max_calls: int = 512, slot_budget: int = 8):
        self.cfg = cfg
        self.max_calls = max_calls
        self.slot_budget = slot_budget
        self.state = E.build(corpus_embeddings, cfg, key)
        self._key = key

    def update_corpus(self, new_embeddings):
        """Dynamic data updates (paper §5) keep the planner fresh without a
        rebuild — the whole point of the non-learned estimator."""
        self.state = E.update(self.state, new_embeddings, self.cfg)

    def estimate(self, q, tau) -> float:
        self._key, sub = jax.random.split(self._key)
        return float(E.estimate(self.state, q, tau, self.cfg, sub))

    def plan(self, q, tau) -> OperatorPlan:
        est = self.estimate(q, tau)
        calls = int(math.ceil(est))
        if calls > self.max_calls:
            return OperatorPlan(est, 0, 0, 0, "refuse",
                                f"estimated {calls} LLM calls > budget "
                                f"{self.max_calls}")
        if calls == 0:
            return OperatorPlan(est, 0, 0, 0, "execute", "no matches")
        slots = min(self.slot_budget, max(1, calls))
        n_batches = int(math.ceil(calls / slots))
        return OperatorPlan(est, calls, slots, n_batches, "execute")
