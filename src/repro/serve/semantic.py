"""Semantic-operator planner — the paper's motivating application (§1):
"estimate the number of interactions with the LLM without actual execution".

A semantic operator (e.g. ``SEM_JOIN docs ON similarity(q) <= tau`` followed
by an LLM call per match) needs the match cardinality BEFORE execution to
pick a plan: batch size, slot count, whether to run at all (cost ceilings).
The planner wraps the Dynamic Prober over the operator's embedding corpus and
converts cardinality estimates into an execution plan for the serving engine.

Concurrent operators share one prober: :meth:`SemanticPlanner.plan_batch`
coalesces every outstanding ``(q, tau)`` into a single jitted
``estimate_batch`` step via the engine's :class:`CardinalityCoalescer`
(DESIGN.md §9), so N simultaneous plan requests cost one hash matmul and
one candidate scan instead of N.
"""
from __future__ import annotations

import dataclasses
import math

import jax

from repro.core import estimator as E
from repro.core.config import ProberConfig
from repro.serve.engine import CardinalityCoalescer


@dataclasses.dataclass
class OperatorPlan:
    est_matches: float
    llm_calls: int            # calls the plan will schedule
    batch_slots: int          # engine slots to provision
    n_batches: int
    action: str               # "execute" | "fallback_exact" | "refuse"
    reason: str = ""


class SemanticPlanner:
    def __init__(self, corpus_embeddings, cfg: ProberConfig, key,
                 max_calls: int = 512, slot_budget: int = 8,
                 max_batch: int = 256, capacity: int | None = None,
                 mesh=None, data_axes=("data",), mode: str = "local",
                 cache_size: int = 0, reuse_tol: float = 0.0):
        """``cache_size``/``reuse_tol`` (DESIGN.md §12) switch on the
        workload-aware estimate cache for repeated operator traffic:
        ``reuse_tol = 0`` reuses only exact-repeat ``(q, tau)`` plans
        (hits bit-identical to a fresh probe, zero extra q-error);
        ``reuse_tol > 0`` also serves LSH near-duplicates whose tau falls
        in the same multiplicative ``(1 + reuse_tol)`` band — higher hit
        rate for a bounded extra q-error. Ingests via
        :meth:`update_corpus` invalidate affected entries exactly (the
        epoch check), so plans never reflect pre-update cardinalities."""
        self.cfg = cfg
        self.max_calls = max_calls
        self.slot_budget = slot_budget
        self._mesh = mesh
        # capacity-padded build (DESIGN.md §10): leave spare rows so corpus
        # updates are recompile-free jitted steps instead of rebuilds. With
        # ``mesh`` the index is SHARDED over its data axes (DESIGN.md §4)
        # and estimates run distributed with the chosen stopping ``mode``.
        if mesh is None:
            self.state = E.build(corpus_embeddings, cfg, key,
                                 capacity=capacity,
                                 track_epochs=cache_size > 0)
        else:
            from repro.core import distributed as D
            self.state, _ = D.build_sharded(corpus_embeddings, cfg, key,
                                            mesh, data_axes=data_axes,
                                            capacity=capacity)
        self._key = key
        self._coalescer = CardinalityCoalescer(self.state, cfg, key,
                                               max_batch=max_batch,
                                               mesh=mesh,
                                               data_axes=data_axes,
                                               mode=mode,
                                               cache_size=cache_size,
                                               reuse_tol=reuse_tol)
        self._cached = cache_size > 0

    @property
    def cache_stats(self) -> dict:
        """Cumulative estimate-cache counters (hits / misses / stale /
        evicts / lookups) of the underlying coalescer."""
        return dict(self._coalescer.cache_stats)

    def update_corpus(self, new_embeddings):
        """Dynamic data updates (paper §5) keep the planner fresh without a
        rebuild — the whole point of the non-learned estimator. Routed
        through the coalescer's ingest path: fixed-chunk capacity-padded
        update steps (DESIGN.md §10), applied before the next estimate."""
        self._coalescer.ingest(new_embeddings)
        self._coalescer.apply_ingest()
        self.state = self._coalescer.state

    def estimate(self, q, tau) -> float:
        # sharded and cached serving both route through the coalescer (the
        # cache lives there; single-shot estimates must hit and fill it too)
        if self._mesh is not None or self._cached:
            return self.estimate_batch([q], [tau])[0]
        self._key, sub = jax.random.split(self._key)
        return float(E.estimate(self.state, q, tau, self.cfg, sub))

    def estimate_batch(self, qs, taus) -> list[float]:
        """Coalesce concurrent requests into one jitted estimate_batch step."""
        reqs = [self._coalescer.submit(q, t) for q, t in zip(qs, taus)]
        self._coalescer.flush()
        return [r.est for r in reqs]

    def _plan_from_estimate(self, est: float) -> OperatorPlan:
        calls = int(math.ceil(est))
        if calls > self.max_calls:
            return OperatorPlan(est, 0, 0, 0, "refuse",
                                f"estimated {calls} LLM calls > budget "
                                f"{self.max_calls}")
        if calls == 0:
            return OperatorPlan(est, 0, 0, 0, "execute", "no matches")
        slots = min(self.slot_budget, max(1, calls))
        n_batches = int(math.ceil(calls / slots))
        return OperatorPlan(est, calls, slots, n_batches, "execute")

    def plan(self, q, tau) -> OperatorPlan:
        return self._plan_from_estimate(self.estimate(q, tau))

    def plan_batch(self, qs, taus) -> list[OperatorPlan]:
        """Plan N concurrent operators off ONE coalesced estimation step."""
        return [self._plan_from_estimate(e)
                for e in self.estimate_batch(qs, taus)]
